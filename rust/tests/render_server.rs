//! RenderServer contract: a parallel batch of N viewers over one shared
//! scene preparation produces per-viewer stats *identical* to N sequential
//! single-viewer runs (and to the legacy single-viewer `App` path), and the
//! batch parallelism improves host throughput on multicore hosts.
//!
//! Kept as a single #[test] so the timing comparison is not perturbed by
//! sibling tests running concurrently in the same process.

use gaucim::camera::ViewCondition;
use gaucim::coordinator::{App, RenderServer, SequenceReport, ViewerSpec};
use gaucim::pipeline::PipelineConfig;
use gaucim::scene::synth::{SceneKind, SynthParams};
use std::time::Instant;

fn assert_reports_identical(a: &SequenceReport, b: &SequenceReport) {
    assert_eq!(a.frames, b.frames);
    assert_eq!(a.energy, b.energy);
    assert_eq!(a.latency, b.latency);
    assert_eq!(a.avg_visible, b.avg_visible);
    assert_eq!(a.avg_dram_accesses, b.avg_dram_accesses);
    assert_eq!(a.avg_dram_bytes, b.avg_dram_bytes);
    assert_eq!(a.sram_hit_rate, b.sram_hit_rate);
    assert_eq!(a.avg_sort_cycles, b.avg_sort_cycles);
    assert_eq!(a.avg_atg_ops, b.avg_atg_ops);
    assert_eq!(a.report.fps, b.report.fps);
    assert_eq!(a.report.power_w, b.report.power_w);
}

#[test]
fn four_viewers_match_sequential_runs_and_scale() {
    // The ISSUE's acceptance scene: 4 viewers on a 4k-Gaussian synthetic
    // dynamic scene.
    let scene = SynthParams::new(SceneKind::DynamicLarge, 4000).with_seed(17).generate();
    let config = PipelineConfig::paper(true).with_resolution(256, 144);
    let frames = 6;
    let server = RenderServer::new(scene.clone(), config.clone());
    let specs = [
        ViewerSpec::perf(ViewCondition::Average, frames),
        ViewerSpec::perf(ViewCondition::Static, frames),
        ViewerSpec::perf(ViewCondition::Extreme, frames),
        ViewerSpec::perf(ViewCondition::Average, frames),
    ];

    // Warm-up run (JIT-ish noise: page cache, branch predictors, allocator).
    server.render_batch(&specs);

    // Sequential single-viewer runs of the same sessions.
    let t0 = Instant::now();
    let sequential: Vec<_> = specs
        .iter()
        .enumerate()
        .map(|(i, s)| server.render_viewer(i, s))
        .collect();
    let seq_wall = t0.elapsed().as_secs_f64();

    // Parallel batch.
    let batch = server.render_batch(&specs);
    assert_eq!(batch.viewers.len(), 4);
    assert_eq!(batch.total_frames, 4 * frames);
    assert!(batch.aggregate_frames_per_s > 0.0);

    // 1) Per-viewer stats identical to sequential runs — determinism across
    //    thread scheduling and shared-prep reuse.
    for (seq_rep, par_rep) in sequential.iter().zip(&batch.viewers) {
        assert_reports_identical(seq_rep, par_rep);
        assert_eq!(seq_rep.label, par_rep.label);
    }

    // 2) Identical to the legacy single-viewer App path (its own private
    //    scene preparation): the server changes *where* prep lives, never
    //    the numbers.
    let app = App {
        scene,
        config,
        orbit_radius: server.orbit_radius,
    };
    let app_rep = app.run_sequence(ViewCondition::Average, frames, 0);
    assert_reports_identical(&app_rep, &batch.viewers[0]);

    // 3) Aggregate throughput: 4 viewers in a batch must beat one viewer's
    //    host throughput. Single-viewer throughput is seq_wall / 4 per
    //    session → frames*4/seq_wall ≈ one viewer's rate. Gated on ≥4
    //    hardware threads: with fewer (or heavily shared) cores a parallel
    //    speedup is not physically guaranteed and the assertion would be
    //    timing-flaky; the multi_viewer example still reports the measured
    //    speedup (BENCH_server.json) on any host.
    let cores = std::thread::available_parallelism().map(usize::from).unwrap_or(1);
    if cores >= 4 {
        let single_viewer_fps = batch.total_frames as f64 / seq_wall;
        assert!(
            batch.aggregate_frames_per_s > single_viewer_fps,
            "batch {:.1} frames/s should beat sequential {:.1} frames/s on {cores} cores \
             (wall: batch {:.3}s vs sequential {:.3}s)",
            batch.aggregate_frames_per_s,
            single_viewer_fps,
            batch.wall_s,
            seq_wall
        );
    }
}
