//! Cross-module integration tests: the full frame pipeline against its
//! baselines and invariants that span culling + tiles + sorting + memory +
//! render.

use gaucim::camera::ViewCondition;
use gaucim::coordinator::App;
use gaucim::pipeline::{FramePipeline, PipelineConfig};
use gaucim::scene::synth::{SceneKind, SynthParams};

fn app(kind: SceneKind, n: usize, w: usize, h: usize) -> App {
    let mut app = App::new(kind, n, 99);
    app.config = app.config.clone().with_resolution(w, h);
    app
}

#[test]
fn optimized_pipeline_renders_same_image_as_baseline() {
    // DR-FC + ATG + AII only change *what is fetched and in which order*,
    // never the pixels.
    let app = app(SceneKind::DynamicLarge, 6000, 256, 144);
    let cam = app.camera_template();
    let t = 0.4;

    let mut opt = FramePipeline::new(&app.scene, app.config.clone());
    let mut base = FramePipeline::new(
        &app.scene,
        PipelineConfig::baseline(true).with_resolution(256, 144),
    );
    let img_opt = opt.render_frame(&cam, t, true).image.unwrap();
    let img_base = base.render_frame(&cam, t, true).image.unwrap();
    assert_eq!(img_opt, img_base, "optimizations must be pixel-exact");
}

#[test]
fn all_optimizations_reduce_traffic_or_work() {
    let app = app(SceneKind::DynamicLarge, 8000, 320, 180);
    let frames = app.trajectory(ViewCondition::Average, 4);

    let run = |config: PipelineConfig| {
        let mut p = FramePipeline::new(&app.scene, config);
        let mut pre_bytes = 0u64;
        let mut blend_bursts = 0u64;
        let mut sort_cycles = 0u64;
        for (cam, t) in &frames {
            let r = p.render_frame(cam, *t, false);
            pre_bytes += r.traffic.preprocess_dram.bytes;
            blend_bursts += r.traffic.blend_dram.bursts;
            sort_cycles += r.sort.cycles;
        }
        (pre_bytes, blend_bursts, sort_cycles)
    };

    let full = run(app.config.clone());
    let no_drfc = run(PipelineConfig { use_drfc: false, ..app.config.clone() });
    let no_atg = run(PipelineConfig { use_atg: false, ..app.config.clone() });
    let no_aii = run(PipelineConfig { use_aii: false, ..app.config.clone() });

    assert!(
        full.0 < no_drfc.0,
        "DR-FC must cut preprocess DRAM: {} vs {}",
        full.0,
        no_drfc.0
    );
    assert!(
        full.1 <= no_atg.1,
        "ATG must not increase blend DRAM bursts: {} vs {}",
        full.1,
        no_atg.1
    );
    assert!(
        full.2 < no_aii.2,
        "AII must cut sort cycles: {} vs {}",
        full.2,
        no_aii.2
    );
}

#[test]
fn scene_roundtrip_preserves_frame_results() {
    let scene = SynthParams::new(SceneKind::DynamicLarge, 3000).generate();
    let path = std::env::temp_dir().join("gaucim_integration_roundtrip.g4d");
    gaucim::scene::io::save(&scene, &path).unwrap();
    let loaded = gaucim::scene::io::load(&path).unwrap();
    std::fs::remove_file(&path).ok();

    let config = PipelineConfig::paper(true).with_resolution(192, 108);
    let mut cam = gaucim::camera::Camera::look_at(
        gaucim::math::Vec3::new(0.0, 4.0, 22.0),
        gaucim::math::Vec3::ZERO,
        gaucim::math::Vec3::new(0.0, 1.0, 0.0),
        60f32.to_radians(),
        16.0 / 9.0,
        0.1,
        200.0,
    );
    cam.set_resolution(192, 108);

    let r1 = FramePipeline::new(&scene, config.clone()).render_frame(&cam, 0.3, true);
    let r2 = FramePipeline::new(&loaded, config).render_frame(&cam, 0.3, true);
    assert_eq!(r1.image.unwrap(), r2.image.unwrap());
    assert_eq!(r1.n_visible, r2.n_visible);
    assert_eq!(r1.traffic.gaussians_fetched, r2.traffic.gaussians_fetched);
}

#[test]
fn dynamic_costs_more_at_paper_scale_ratio() {
    // Paper workloads: dynamic scenes carry ~2x the primitives of static
    // ones (temporal expansion), a larger per-record footprint, and a
    // bigger DCIM tier — at that ratio the dynamic config costs more per
    // frame (Table I: 0.63 W vs 0.28 W) even though temporal culling keeps
    // its *visible* fraction small.
    let d = app(SceneKind::DynamicLarge, 20_000, 320, 180);
    let s = app(SceneKind::StaticLarge, 8_000, 320, 180);
    let rd = d.run_sequence(ViewCondition::Average, 3, 0);
    let rs = s.run_sequence(ViewCondition::Static, 3, 0);
    assert!(
        rd.avg_dram_bytes > rs.avg_dram_bytes * 0.5,
        "dynamic {} B vs static {} B",
        rd.avg_dram_bytes,
        rs.avg_dram_bytes
    );
    assert!(rd.report.area_mm2 > rs.report.area_mm2);
    // Per fetched gaussian, dynamic records are strictly larger.
    assert!(
        gaucim::scene::Gaussian4D::dram_bytes(true)
            > gaucim::scene::Gaussian4D::dram_bytes(false)
    );
}

#[test]
fn sequence_determinism() {
    let a1 = app(SceneKind::DynamicLarge, 4000, 256, 144);
    let a2 = app(SceneKind::DynamicLarge, 4000, 256, 144);
    let r1 = a1.run_sequence(ViewCondition::Average, 3, 0);
    let r2 = a2.run_sequence(ViewCondition::Average, 3, 0);
    assert_eq!(r1.avg_dram_accesses, r2.avg_dram_accesses);
    assert_eq!(r1.avg_sort_cycles, r2.avg_sort_cycles);
    assert!((r1.report.fps - r2.report.fps).abs() < 1e-9);
}

#[test]
fn posteriori_state_survives_and_helps_across_sequence() {
    let app = app(SceneKind::DynamicLarge, 20_000, 320, 180);
    let frames = app.trajectory(ViewCondition::Average, 6);
    let mut p = FramePipeline::new(&app.scene, app.config.clone());
    let mut first_sort = 0u64;
    let mut rest_sort = 0u64;
    let mut rest_frames = 0u64;
    for (i, (cam, t)) in frames.iter().enumerate() {
        let r = p.render_frame(cam, *t, false);
        if i == 0 {
            first_sort = r.sort.minmax_scanned;
        } else {
            rest_sort += r.sort.minmax_scanned;
            rest_frames += 1;
        }
    }
    assert!(first_sort > 0, "frame 0 pays the min/max scan");
    // Later frames only pay phase 1 for tile blocks that were empty so far;
    // the overwhelming majority of elements ride the posteriori boundaries.
    let per_frame_later = rest_sort as f64 / rest_frames.max(1) as f64;
    assert!(
        per_frame_later < 0.25 * first_sort as f64,
        "posteriori must eliminate most min/max scans: frame0 {first_sort},          later {per_frame_later}/frame"
    );
}
