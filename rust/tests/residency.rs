//! Residency-layer contract: DRAM as a shard-granular cache over the
//! compressed backing store.
//!
//! * Fully-resident configs (residency off, or capacity at/above the scene
//!   span) must be **byte-identical** to the direct path — the paging layer
//!   may not perturb a single simulated number when it has nothing to do.
//! * Sub-capacity runs must be bit-identical across the host thread matrix
//!   (1/4/8) for every prefetch policy — paging traffic replays in policy
//!   order, never host-scheduling order.
//! * Shrinking the capacity must strictly raise demand-stall time (the
//!   eviction/refetch loop is really modeled, not just counted).
//! * The compressed record format round-trips bit-exactly, and the
//!   trajectory-lookahead prefetcher beats no-prefetch on the standard
//!   orbit trajectory.

use gaucim::camera::ViewCondition;
use gaucim::coordinator::{RenderServer, ViewerSpec};
use gaucim::memory::{PrefetchPolicy, ResidencyReport};
use gaucim::pipeline::PipelineConfig;
use gaucim::scene::synth::{SceneKind, SynthParams};
use gaucim::scene::Scene;

fn scene() -> Scene {
    SynthParams::new(SceneKind::DynamicLarge, 4000).with_seed(42).generate()
}

fn server_with(capacity_mb: f64, policy: PrefetchPolicy) -> RenderServer {
    let mut config = PipelineConfig::paper(true).with_resolution(192, 108).with_threads(1);
    // Explicit capacity: tests must not inherit PALLAS_RESIDENCY_MB.
    config.mem.residency.capacity_mb = capacity_mb;
    config.mem.residency.policy = policy;
    RenderServer::new(scene(), config)
}

fn specs(frames: usize) -> Vec<ViewerSpec> {
    vec![
        ViewerSpec::perf(ViewCondition::Average, frames),
        ViewerSpec::perf(ViewCondition::Extreme, frames),
    ]
}

/// Scene span in MiB, read off a probe preparation's compressed store.
fn span_mb() -> f64 {
    let probe = server_with(1e-4, PrefetchPolicy::None);
    let store = probe.shared.prep.compressed.as_ref().expect("probe builds the store");
    store.span_bytes() as f64 / (1u64 << 20) as f64
}

fn residency_block(server: &RenderServer, specs: &[ViewerSpec]) -> ResidencyReport {
    server
        .render_batch_contended(specs)
        .contended_mem
        .as_ref()
        .expect("contended roll-up")
        .residency
        .expect("sub-capacity run must report residency")
}

#[test]
fn fully_resident_is_byte_identical_to_direct_path() {
    let specs = specs(3);
    let off = server_with(0.0, PrefetchPolicy::None);
    let off_rep = off.render_batch_contended(&specs);
    // Capacity well above the span: the store is built, but the paging
    // layer must detach itself and change nothing.
    let over = server_with(span_mb() * 4.0, PrefetchPolicy::TrajectoryLookahead { k: 2 });
    let over_rep = over.render_batch_contended(&specs);

    assert!(off_rep.contended_mem.as_ref().unwrap().residency.is_none());
    assert!(over_rep.contended_mem.as_ref().unwrap().residency.is_none());
    assert_eq!(
        off_rep.simulated_projection(),
        over_rep.simulated_projection(),
        "an at-capacity residency config must not perturb the direct path"
    );
}

#[test]
fn thread_matrix_is_bit_identical_per_policy() {
    let specs = specs(3);
    let half = span_mb() * 0.5;
    for policy in [
        PrefetchPolicy::None,
        PrefetchPolicy::NextFrameCull,
        PrefetchPolicy::TrajectoryLookahead { k: 2 },
    ] {
        let mut server = server_with(half, policy);
        let reference = server.render_batch_contended(&specs).simulated_projection();
        for threads in [4usize, 8] {
            server.set_threads(threads);
            assert_eq!(
                reference,
                server.render_batch_contended(&specs).simulated_projection(),
                "paged batch diverged at {threads} threads ({})",
                policy.label()
            );
        }
        server.set_threads(1);
        let res = residency_block(&server, &specs);
        assert!(
            res.stats.demand_fills + res.stats.prefetch_fills > 0,
            "a half-capacity run must page ({})",
            policy.label()
        );
        assert!(res.compression_ratio > 1.0);
    }
}

#[test]
fn smaller_capacity_strictly_raises_stall_time() {
    let specs = specs(4);
    let span = span_mb();
    let half = residency_block(&server_with(span * 0.5, PrefetchPolicy::None), &specs);
    let eighth = residency_block(&server_with(span * 0.125, PrefetchPolicy::None), &specs);
    assert!(half.stats.stall_ns > 0.0, "cold demand fills must stall");
    assert!(
        eighth.stats.stall_ns > half.stats.stall_ns,
        "an eighth of the span must stall strictly longer than half ({} vs {} ns)",
        eighth.stats.stall_ns,
        half.stats.stall_ns
    );
    assert!(eighth.stats.evictions > half.stats.evictions);
    assert!(eighth.capacity_pages < half.capacity_pages);
}

#[test]
fn compressed_records_round_trip_bit_exactly() {
    let probe = server_with(1e-4, PrefetchPolicy::None);
    let prep = &probe.shared.prep;
    let store = prep.compressed.as_ref().unwrap();
    let stride = prep.layout.bytes_per_gaussian.max(1);
    for (ci, &(start, end)) in prep.layout.cell_ranges.iter().enumerate() {
        let i0 = (start / stride) as usize;
        let i1 = (end / stride) as usize;
        let decoded = store.decode_cell(ci);
        assert_eq!(decoded.len(), i1 - i0);
        for (k, &gi) in prep.layout.order[i0..i1].iter().enumerate() {
            assert_eq!(
                decoded[k], prep.quantized[gi as usize],
                "cell {ci} record {k} (gaussian {gi}) did not round-trip"
            );
        }
    }
    assert!(store.compression_ratio() > 1.0, "delta/FP16 coding must compress");
    assert!(store.total_compressed_bytes() < store.span_bytes());
}

#[test]
fn trajectory_lookahead_beats_no_prefetch() {
    let specs = specs(4);
    let half = span_mb() * 0.5;
    let none = residency_block(&server_with(half, PrefetchPolicy::None), &specs);
    let ahead = residency_block(
        &server_with(half, PrefetchPolicy::TrajectoryLookahead { k: 2 }),
        &specs,
    );
    assert!(
        ahead.stats.hit_rate() > none.stats.hit_rate(),
        "lookahead must raise the hit rate on the standard trajectory ({} vs {})",
        ahead.stats.hit_rate(),
        none.stats.hit_rate()
    );
    assert!(ahead.stats.prefetch_fills > 0);
    assert_eq!(none.stats.prefetch_fills, 0);
}
