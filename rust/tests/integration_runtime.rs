//! End-to-end integration through the PJRT runtime: AOT artifacts
//! (L2 preprocess + L1 Pallas blend) driven from the L3 coordinator's data
//! structures, cross-checked against the native pipeline.
//!
//! Skips gracefully (with a note) when `make artifacts` has not run.
//! The whole target is gated on the `xla` feature (see Cargo.toml
//! `required-features`); the inner cfg is belt-and-suspenders.

#![cfg(feature = "xla")]

use gaucim::coordinator::App;
use gaucim::runtime::{Artifacts, BlendExecutor, HloExecutor, PreprocessExecutor};
use gaucim::scene::synth::SceneKind;
use gaucim::tiles::intersect::project_gaussian;

fn artifacts() -> Option<Artifacts> {
    match Artifacts::discover() {
        Ok(a) if a.available() => Some(a),
        _ => {
            eprintln!("skipping PJRT integration: run `make artifacts` first");
            None
        }
    }
}

#[test]
fn full_chunked_preprocess_matches_native() {
    let Some(artifacts) = artifacts() else { return };
    let client = HloExecutor::cpu_client().unwrap();
    let pre = PreprocessExecutor::load(&client, &artifacts.preprocess_hlo()).unwrap();

    let mut app = App::new(SceneKind::DynamicLarge, 2500, 5);
    app.config = app.config.clone().with_resolution(320, 180);
    let cam = app.camera_template();
    let t = 0.42;

    // Chunked PJRT preprocessing over the whole scene.
    let mut pjrt_splats = Vec::new();
    for (ci, chunk) in app.scene.gaussians.chunks(1024).enumerate() {
        let out = pre
            .project_chunk(chunk, (ci * 1024) as u32, &cam, t)
            .unwrap();
        pjrt_splats.extend(out);
    }

    // Native projection over the same primitives.
    let native: Vec<_> = app
        .scene
        .gaussians
        .iter()
        .enumerate()
        .filter_map(|(i, g)| project_gaussian(g, i as u32, &cam, t))
        .collect();

    let native_ids: std::collections::HashSet<u32> = native.iter().map(|s| s.id).collect();
    let pjrt_ids: std::collections::HashSet<u32> = pjrt_splats.iter().map(|s| s.id).collect();
    let agree = native_ids.intersection(&pjrt_ids).count();
    assert!(
        agree as f64 >= 0.97 * native_ids.len().max(1) as f64,
        "visibility agreement {agree}/{}",
        native_ids.len()
    );
}

#[test]
fn pjrt_blend_composes_with_sorted_pipeline_output() {
    let Some(artifacts) = artifacts() else { return };
    let client = HloExecutor::cpu_client().unwrap();
    let pre = PreprocessExecutor::load(&client, &artifacts.preprocess_hlo()).unwrap();
    let blend = BlendExecutor::load(&client, &artifacts.blend_hlo()).unwrap();

    let mut app = App::new(SceneKind::StaticLarge, 1500, 5);
    app.config = app.config.clone().with_resolution(320, 180);
    let cam = app.camera_template();

    let mut splats = pre
        .project_chunk(&app.scene.gaussians, 0, &cam, 0.0)
        .unwrap();
    splats.sort_by(|a, b| a.depth.partial_cmp(&b.depth).unwrap());
    // Center tile of the image.
    let x0 = (cam.intrinsics.cx - 8.0).floor();
    let y0 = (cam.intrinsics.cy - 8.0).floor();
    let tile_splats: Vec<_> = splats
        .iter()
        .filter(|s| {
            s.mean.x + s.radius >= x0
                && s.mean.x - s.radius < x0 + 16.0
                && s.mean.y + s.radius >= y0
                && s.mean.y - s.radius < y0 + 16.0
        })
        .cloned()
        .collect();

    let pjrt_tile = blend.blend_tile(&tile_splats, x0, y0).unwrap();
    let native = gaucim::runtime::blend_exec::cumulative_blend_reference(&tile_splats, x0, y0);
    for (i, (a, b)) in pjrt_tile.iter().zip(&native).enumerate() {
        for c in 0..3 {
            assert!(
                (a[c] - b[c]).abs() < 2e-2,
                "pixel {i} ch {c}: {} vs {}",
                a[c],
                b[c]
            );
        }
    }
    // The tile must contain actual content (scene center is populated).
    let max = pjrt_tile
        .iter()
        .flat_map(|p| p.iter().copied())
        .fold(0.0f32, f32::max);
    assert!(max > 0.05, "center tile should not be empty: max {max}");
}

#[test]
fn exp_lut_artifact_matches_rust_dcim_model() {
    let Some(artifacts) = artifacts() else { return };
    let client = HloExecutor::cpu_client().unwrap();
    let exe = HloExecutor::load(&client, &artifacts.exp_lut_hlo()).unwrap();

    let n = gaucim::runtime::EXP_LUT_N;
    let xs: Vec<f32> = (0..n).map(|i| -30.0 + 40.0 * i as f32 / n as f32).collect();
    let lit = gaucim::runtime::executor::literal_f32(&xs, &[n as i64]).unwrap();
    let out = exe.run(&[lit]).unwrap();
    let got = gaucim::runtime::executor::to_vec_f32(&out[0]).unwrap();

    let lut = gaucim::dcim::ExpLut::paper();
    for (i, (&x, &g)) in xs.iter().zip(&got).enumerate() {
        let expect = lut.exp2(x);
        let tol = 2e-3 * expect.abs() + 1e-12;
        assert!(
            (g - expect).abs() <= tol,
            "i={i} x={x}: pjrt {g} vs rust lut {expect}"
        );
    }
}
