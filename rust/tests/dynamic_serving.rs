//! Dynamic-serving contract (`scene::temporal` + the `MemStage::Update`
//! stream + the temporal-coherence savings built on top):
//!
//! 1. **Temporal codec** — the XOR-delta/FP16 update stream round-trips
//!    exactly: re-advancing at an already-applied scene time finds every
//!    cell clean and ships zero bytes; dirty frames ship one write burst
//!    per dirty cell, and the delta is strictly smaller than a raw
//!    full-record refresh.
//! 2. **Thread matrix** — dynamic sessions (update writes contending with
//!    render reads) replay byte-identically at `PALLAS_THREADS = 1/4/8`
//!    under every scheduling policy (lockstep vs two-phase trace/replay,
//!    with update-write traces recorded alongside read traces).
//! 3. **Cull reuse** — dirty-cell-aware cull reuse driven by the real
//!    update stream's dirty flags produces outputs bit-identical to a full
//!    re-cull while fetching strictly fewer DRAM bytes.
//! 4. **AII retention** — keeping posteriori intervals live across scene
//!    updates renders bit-identical frames with strictly fewer
//!    `minmax_scanned` (and sort cycles) than the cold-start policy.
//! 5. **Static regression** — static-scene reports carry no `update_dram`
//!    / `dynamic` keys and register no update ports: byte-identical to a
//!    build without the feature.

use gaucim::camera::{Camera, ViewCondition};
use gaucim::coordinator::App;
use gaucim::coordinator::{RenderServer, SchedPolicy, SessionScript, SessionSpec};
use gaucim::culling::{CullOutput, CullReuse, CullReuseStats, DrFc, GridConfig, GridPartition};
use gaucim::math::Vec3;
use gaucim::memory::DramModel;
use gaucim::pipeline::{FramePipeline, PipelineConfig};
use gaucim::scene::synth::{SceneKind, SynthParams};
use gaucim::scene::{DramLayout, Scene, TemporalStream, UpdateFrameStats};

fn scene_prep(n: usize) -> (Scene, GridPartition, DramLayout) {
    let scene = SynthParams::new(SceneKind::DynamicLarge, n).with_seed(9).generate();
    let grid = GridPartition::build(&scene, GridConfig::new(4));
    let layout = DramLayout::build(&scene, &grid);
    (scene, grid, layout)
}

#[test]
fn temporal_delta_round_trips_exactly_and_clean_cells_ship_zero_bytes() {
    let (scene, grid, layout) = scene_prep(800);
    let n_cells = grid.cells.len();
    let mut ts = TemporalStream::new(scene.dynamic, scene.len(), n_cells);

    // Frame 0 bakes the baseline: scene prep, not an update — nothing ships.
    let s0 = ts.advance(&scene.gaussians, &layout, 0.1);
    assert_eq!(s0, UpdateFrameStats::default());
    assert!(ts.take_writes().is_empty());
    assert!(ts.dirty_cells().iter().all(|&d| !d), "baseline reads clean");

    // Frame 1 at a new scene time ships deltas for moved cells only.
    let s1 = ts.advance(&scene.gaussians, &layout, 0.6);
    assert!(s1.updated_records > 0, "a dynamic scene must move between frames");
    assert!(s1.delta_bytes > 0);
    assert!(
        s1.delta_bytes < s1.raw_bytes,
        "XOR-delta ({}) must undercut a raw refresh ({})",
        s1.delta_bytes,
        s1.raw_bytes
    );
    let writes = ts.take_writes();
    assert_eq!(writes.len() as u64, s1.dirty_cells, "one write burst per dirty cell");
    assert!(writes.iter().all(|&(_, bytes)| bytes > 0));

    // Round-trip exactness: the stream applied its own deltas to the
    // baseline, so re-advancing at the same scene time finds every record
    // image already bit-equal — all cells read clean, zero bytes ship.
    let s2 = ts.advance(&scene.gaussians, &layout, 0.6);
    assert_eq!(s2.dirty_cells, 0, "applied deltas must reproduce the frame exactly");
    assert_eq!(s2.updated_records, 0);
    assert_eq!(s2.delta_bytes, 0);
    let nonempty = layout.cell_ranges.iter().filter(|&&(s, e)| e > s).count();
    assert_eq!(s2.clean_cells as usize, nonempty, "every occupied cell reads clean");
    assert!(ts.take_writes().is_empty());
}

fn dynamic_server(threads: usize) -> RenderServer {
    let scene = SynthParams::new(SceneKind::DynamicLarge, 1500).with_seed(21).generate();
    let mut config =
        PipelineConfig::paper(true).with_resolution(128, 72).with_threads(threads);
    config.dynamic_updates = true;
    RenderServer::new(scene, config)
}

fn join_leave_script() -> SessionScript {
    SessionScript::new()
        .join_at(0, SessionSpec::stream(ViewCondition::Average, 5).with_deadline_fps(120.0))
        .join_at(
            0,
            SessionSpec::stream(ViewCondition::Static, 5)
                .with_deadline_fps(60.0)
                .with_weight(2.0),
        )
        .join_at(
            2,
            SessionSpec::stream(ViewCondition::Extreme, 3)
                .with_start(2)
                .with_deadline_fps(90.0),
        )
        .leave_at(4, 0)
}

#[test]
fn dynamic_sessions_replay_byte_identically_across_thread_counts_per_policy() {
    let script = join_leave_script();
    for policy in SchedPolicy::ALL {
        let baseline = dynamic_server(1).render_sessions(&script, policy);
        // The update stream actually flowed: per-session dynamic blocks and
        // contended update rows are populated.
        assert!(
            baseline.sessions.iter().filter(|s| s.frames > 1).all(|s| {
                s.seq.dynamic.is_some_and(|d| d.update.updated_records > 0)
            }),
            "{}: multi-frame dynamic sessions must ship updates",
            policy.label()
        );
        assert!(
            baseline
                .contended
                .viewers
                .iter()
                .all(|v| v.update.is_some_and(|u| u.bytes > 0)),
            "{}: every admitted session must own a live update port",
            policy.label()
        );
        let projection = baseline.simulated_projection();
        for threads in [4, 8] {
            assert_eq!(
                projection,
                dynamic_server(threads).render_sessions(&script, policy).simulated_projection(),
                "{} dynamic stream diverged at threads={threads}",
                policy.label()
            );
        }
    }
}

#[test]
fn update_driven_cull_reuse_matches_full_recull_bit_exactly() {
    let (scene, grid, layout) = scene_prep(2000);
    let drfc = DrFc::new(&scene, &grid, &layout);
    let cam = Camera::look_at(
        Vec3::new(0.0, 4.0, 25.0),
        Vec3::ZERO,
        Vec3::new(0.0, 1.0, 0.0),
        60f32.to_radians(),
        16.0 / 9.0,
        0.1,
        200.0,
    );
    let pass1 = |out: &mut CullOutput, t: f32| {
        out.clear();
        let frustum = cam.frustum();
        for flat in drfc.slice_cell_range(t) {
            if drfc.cell_test(flat, &frustum) {
                out.visible_cells.push(flat);
            }
        }
    };

    // Stream five frames: the real update stream dirties cells, reuse
    // invalidates from those flags, and every frame's reuse outputs must
    // equal the full re-cull bit-for-bit while DRAM traffic only shrinks.
    let mut ts = TemporalStream::new(scene.dynamic, scene.len(), grid.cells.len());
    let mut reuse = CullReuse::new(grid.cells.len(), scene.len());
    let mut totals = CullReuseStats::default();
    let (mut full_bytes, mut reuse_bytes) = (0u64, 0u64);
    let mut full_out = CullOutput::default();
    let mut reuse_out = CullOutput::default();
    for i in 0..5 {
        let t = 0.1 + 0.08 * i as f32;
        ts.advance(&scene.gaussians, &layout, t);
        reuse.invalidate(ts.dirty_cells(), ts.dirty_records());

        let mut d_full = DramModel::default_lpddr5();
        pass1(&mut full_out, t);
        drfc.cull_scheduled(&cam, t, &mut d_full, &mut full_out);

        let mut d_reuse = DramModel::default_lpddr5();
        pass1(&mut reuse_out, t);
        let stats =
            drfc.cull_scheduled_reuse(&cam, t, &mut d_reuse, &mut reuse_out, &mut reuse);

        assert_eq!(reuse_out.visible_cells, full_out.visible_cells, "frame {i}");
        assert_eq!(reuse_out.candidates, full_out.candidates, "frame {i}");
        assert_eq!(reuse_out.visible, full_out.visible, "frame {i}");
        assert_eq!(reuse_out.fetched, full_out.fetched, "frame {i}");
        assert!(
            d_reuse.stats().bytes <= d_full.stats().bytes,
            "frame {i}: reuse must never fetch more than the full pass"
        );
        full_bytes += d_full.stats().bytes;
        reuse_bytes += d_reuse.stats().bytes;
        totals.add(&stats);
    }
    assert!(
        reuse_bytes < full_bytes,
        "clean cells must replay prior fetches ({reuse_bytes} vs {full_bytes} bytes)"
    );
    assert!(totals.cells_reused > 0, "some visible cells must stay clean across frames");
    assert!(totals.bytes_saved > 0);
    assert_eq!(totals.bytes_saved, full_bytes - reuse_bytes);
}

#[test]
fn aii_retention_is_bit_identical_with_strictly_fewer_minmax_scans() {
    let mut app = App::new(SceneKind::DynamicLarge, 1500, 21);
    app.config = app.config.clone().with_resolution(128, 72);
    let mut warm_cfg = app.config.clone();
    warm_cfg.dynamic_updates = true;
    assert!(warm_cfg.aii_retain, "retention is the default");
    let mut cold_cfg = warm_cfg.clone();
    cold_cfg.aii_retain = false;

    let seq = app.trajectory(ViewCondition::Average, 5);
    let mut warm = FramePipeline::new(&app.scene, warm_cfg);
    let mut cold = FramePipeline::new(&app.scene, cold_cfg);
    let (mut warm_minmax, mut cold_minmax) = (0u64, 0u64);
    let (mut warm_cycles, mut cold_cycles) = (0u64, 0u64);
    for (i, (cam, t)) in seq.iter().enumerate() {
        let rw = warm.render_frame(cam, *t, true);
        let rc = cold.render_frame(cam, *t, true);
        // Bit-identical sort *output*: the blended image and everything
        // downstream of the sorted order must match exactly.
        assert_eq!(
            rw.image.as_ref().expect("rendered").data,
            rc.image.as_ref().expect("rendered").data,
            "frame {i}: retained-AII frame diverged from cold-start"
        );
        assert_eq!(rw.n_visible, rc.n_visible, "frame {i}");
        assert_eq!(
            rw.traffic.total_dram_bytes(),
            rc.traffic.total_dram_bytes(),
            "frame {i}: retention must not change what is transferred"
        );
        assert_eq!(rw.update, rc.update, "frame {i}: identical update streams");
        warm_minmax += rw.sort.minmax_scanned;
        cold_minmax += rc.sort.minmax_scanned;
        warm_cycles += rw.sort.cycles;
        cold_cycles += rc.sort.cycles;
    }
    assert!(
        warm_minmax < cold_minmax,
        "posteriori intervals must skip min/max scans ({warm_minmax} vs {cold_minmax})"
    );
    assert!(
        warm_cycles < cold_cycles,
        "retained sort must cost fewer cycles ({warm_cycles} vs {cold_cycles})"
    );
}

#[test]
fn static_runs_emit_no_dynamic_keys() {
    // Sequence path: a static scene through the standard App run — the
    // report JSON must not grow `dynamic` / `update_dram` keys.
    let mut app = App::new(SceneKind::StaticLarge, 1200, 7);
    app.config = app.config.clone().with_resolution(128, 72);
    let rep = app.run_sequence(ViewCondition::Static, 2, 0);
    assert!(rep.dynamic.is_none());
    let js = rep.to_json().pretty();
    assert!(!js.contains("update"), "static sequence report grew an update key:\n{js}");
    assert!(!js.contains("dynamic"), "static sequence report grew a dynamic key:\n{js}");

    // Contended server path: no update ports register, no `update` rows
    // appear in the shared roll-up.
    let scene = SynthParams::new(SceneKind::StaticLarge, 1200).with_seed(7).generate();
    let config = PipelineConfig::paper(false).with_resolution(128, 72).with_threads(1);
    assert!(!config.dynamic_updates, "static default keeps the update stream off");
    let server = RenderServer::new(scene, config);
    let script = SessionScript::new()
        .join_at(0, SessionSpec::stream(ViewCondition::Static, 2))
        .join_at(0, SessionSpec::stream(ViewCondition::Average, 2));
    let sessions = server.render_sessions(&script, SchedPolicy::RoundRobin);
    assert!(sessions.sessions.iter().all(|s| s.seq.dynamic.is_none()));
    assert!(sessions.contended.viewers.iter().all(|v| v.update.is_none()));
    let mem_js = sessions.contended.to_json().pretty();
    assert!(!mem_js.contains("update"), "static roll-up grew an update key:\n{mem_js}");
}
