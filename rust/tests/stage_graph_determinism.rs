//! Stage-graph determinism suite.
//!
//! 1. **Bit-identity**: a trajectory through the new stage-graph
//!    `FramePipeline` must produce per-frame stat outputs *identical* to
//!    the frozen pre-refactor monolith (`pipeline::oracle::MonolithPipeline`)
//!    — `TrafficLog`, `SortStats`, energy, latency, `n_visible`, blend
//!    pairs, ATG work, and rendered pixels. This is what licenses the
//!    refactor (and the `partition_point` depth-segment replacement).
//! 2. **Zero steady-state scratch allocations**: on a static trajectory the
//!    pooled `FrameCtx` buffers must stop growing after warm-up — their
//!    capacity signature is frozen from the second frame on.
//! 3. **Thread-count invariance**: the `pipeline::par` executor must
//!    produce bit-identical stat outputs (and pixels) at `threads = 1, 2,
//!    8` — parallelism moves host wall-clock only, never simulated
//!    results.

use gaucim::camera::{Camera, Trajectory, ViewCondition};
use gaucim::math::Vec3;
use gaucim::pipeline::oracle::MonolithPipeline;
use gaucim::pipeline::{FramePipeline, FrameResult, PipelineConfig};
use gaucim::scene::synth::{SceneKind, SynthParams};
use gaucim::scene::Scene;

fn template(w: usize, h: usize) -> Camera {
    let mut c = Camera::look_at(
        Vec3::new(0.0, 4.0, 20.0),
        Vec3::ZERO,
        Vec3::new(0.0, 1.0, 0.0),
        60f32.to_radians(),
        w as f32 / h as f32,
        0.1,
        200.0,
    );
    c.set_resolution(w, h);
    c
}

fn trajectory(
    scene: &Scene,
    cond: ViewCondition,
    frames: usize,
    w: usize,
    h: usize,
) -> Vec<(Camera, f32)> {
    let (t0, t1) = scene.time_span;
    Trajectory::new(cond, frames)
        .with_scene(Vec3::new(0.0, 1.0, 0.0), 24.0)
        .with_time_span(t0, t1)
        .generate(&template(w, h))
}

/// Drive both engines over `frames` and assert every stat output matches
/// bit-for-bit. `render_every` exercises the numeric path (exact blend
/// pairs + image + early-termination calibration) on a subset of frames.
fn assert_engines_identical(
    scene: &Scene,
    config: PipelineConfig,
    cond: ViewCondition,
    frames: usize,
    render_every: usize,
) {
    let seq = trajectory(scene, cond, frames, config.width, config.height);
    let mut graph = FramePipeline::new(scene, config.clone());
    let mut oracle = MonolithPipeline::new(scene, config);
    for (i, (cam, t)) in seq.iter().enumerate() {
        let render = render_every > 0 && i % render_every == 0;
        let a = graph.render_frame(cam, *t, render);
        let b = oracle.render_frame(cam, *t, render);
        assert_eq!(a.traffic, b.traffic, "frame {i}: TrafficLog diverged");
        assert_eq!(a.sort, b.sort, "frame {i}: SortStats diverged");
        assert_eq!(a.energy, b.energy, "frame {i}: FrameEnergy diverged");
        assert_eq!(a.latency, b.latency, "frame {i}: StageLatency diverged");
        assert_eq!(a.n_visible, b.n_visible, "frame {i}: n_visible diverged");
        assert_eq!(a.blend_pairs, b.blend_pairs, "frame {i}: blend_pairs diverged");
        assert_eq!(a.intersections, b.intersections, "frame {i}: intersections diverged");
        assert_eq!(a.atg_ops, b.atg_ops, "frame {i}: atg_ops diverged");
        assert_eq!(a.atg_flags, b.atg_flags, "frame {i}: atg_flags diverged");
        assert_eq!(a.image, b.image, "frame {i}: rendered pixels diverged");
        assert_eq!(
            graph.et_factor(),
            oracle.et_factor(),
            "frame {i}: early-termination calibration diverged"
        );
    }
}

#[test]
fn stage_graph_matches_monolith_paper_config() {
    let scene = SynthParams::new(SceneKind::DynamicLarge, 5000).with_seed(11).generate();
    let config = PipelineConfig::paper(true).with_resolution(256, 144);
    // 4-frame trajectory, frame 0 rendered numerically (exercises the exact
    // blend-pair path + et calibration feeding the later modeled frames).
    assert_engines_identical(&scene, config, ViewCondition::Average, 4, 4);
}

#[test]
fn stage_graph_matches_monolith_static_scene() {
    let scene = SynthParams::new(SceneKind::StaticLarge, 3000).with_seed(5).generate();
    let config = PipelineConfig::paper(false).with_resolution(192, 108);
    assert_engines_identical(&scene, config, ViewCondition::Static, 4, 2);
}

#[test]
fn stage_graph_matches_monolith_all_ablations() {
    // The ablation switches route through different stage internals
    // (conventional cull, raster order, conventional sort) — all must stay
    // bit-identical too.
    let scene = SynthParams::new(SceneKind::DynamicLarge, 3000).with_seed(7).generate();
    let base = PipelineConfig::paper(true).with_resolution(160, 96);
    for (drfc, atg, aii) in
        [(false, true, true), (true, false, true), (true, true, false), (false, false, false)]
    {
        let config = PipelineConfig {
            use_drfc: drfc,
            use_atg: atg,
            use_aii: aii,
            ..base.clone()
        };
        assert_engines_identical(&scene, config, ViewCondition::Average, 3, 0);
    }
}

fn assert_frames_identical(a: &FrameResult, b: &FrameResult, label: &str) {
    assert_eq!(a.traffic, b.traffic, "{label}: TrafficLog diverged");
    assert_eq!(a.sort, b.sort, "{label}: SortStats diverged");
    assert_eq!(a.energy, b.energy, "{label}: FrameEnergy diverged");
    assert_eq!(a.latency, b.latency, "{label}: StageLatency diverged");
    assert_eq!(a.n_visible, b.n_visible, "{label}: n_visible diverged");
    assert_eq!(a.blend_pairs, b.blend_pairs, "{label}: blend_pairs diverged");
    assert_eq!(a.intersections, b.intersections, "{label}: intersections diverged");
    assert_eq!(a.atg_ops, b.atg_ops, "{label}: atg_ops diverged");
    assert_eq!(a.atg_flags, b.atg_flags, "{label}: atg_flags diverged");
    assert_eq!(a.image, b.image, "{label}: rendered pixels diverged");
}

#[test]
fn thread_counts_do_not_change_any_stat_output() {
    let scene = SynthParams::new(SceneKind::DynamicLarge, 4000).with_seed(13).generate();
    let base = PipelineConfig::paper(true).with_resolution(192, 108);
    let seq = trajectory(&scene, ViewCondition::Average, 3, 192, 108);
    let run = |config: PipelineConfig| -> Vec<FrameResult> {
        let mut p = FramePipeline::new(&scene, config);
        // Frame 0 renders numerically: the tile-parallel rasterizer, exact
        // blend pairs, and the early-termination calibration all cross the
        // fan-out.
        seq.iter()
            .enumerate()
            .map(|(i, (cam, t))| p.render_frame(cam, *t, i == 0))
            .collect()
    };

    let serial = run(PipelineConfig { threads: 1, ..base.clone() });
    for threads in [2, 8] {
        let par = run(PipelineConfig { threads, ..base.clone() });
        for (i, (a, b)) in serial.iter().zip(&par).enumerate() {
            assert_frames_identical(a, b, &format!("threads={threads} frame={i}"));
        }
    }

    // The event-queue memory backend must be thread-count invariant too
    // (the blend miss replay preserves global request order).
    let mut eq_cfg = base.clone();
    eq_cfg.mem = gaucim::memory::MemSimConfig::event_queue();
    let eq_serial = run(PipelineConfig { threads: 1, ..eq_cfg.clone() });
    let eq_par = run(PipelineConfig { threads: 4, ..eq_cfg });
    for (i, (a, b)) in eq_serial.iter().zip(&eq_par).enumerate() {
        assert_frames_identical(a, b, &format!("event-queue threads=4 frame={i}"));
    }
}

#[test]
fn drfc_cell_fanout_is_thread_invariant() {
    // The cull stage's DR-FC pass-1 fan-out (grid-cell tests chunked per
    // worker, partials concatenated in worker order): a dense grid
    // (grid_n = 8 → many cells per temporal slice) makes every worker
    // chunk non-empty, and the extreme condition moves the frustum so the
    // visible-cell set changes every frame. All stat outputs — most
    // directly the preprocess DRAM stream scheduled from the visible-cell
    // list — must be bit-identical at threads = 1, 2, 8.
    let scene = SynthParams::new(SceneKind::DynamicLarge, 4000).with_seed(23).generate();
    let base = PipelineConfig {
        grid_n: 8,
        ..PipelineConfig::paper(true).with_resolution(160, 96)
    };
    let seq = trajectory(&scene, ViewCondition::Extreme, 3, 160, 96);
    let run = |config: PipelineConfig| -> Vec<FrameResult> {
        let mut p = FramePipeline::new(&scene, config);
        seq.iter().map(|(cam, t)| p.render_frame(cam, *t, false)).collect()
    };

    let serial = run(PipelineConfig { threads: 1, ..base.clone() });
    assert!(
        serial.iter().all(|r| r.traffic.preprocess_dram.bytes > 0),
        "the fan-out must schedule real cull traffic"
    );
    for threads in [2, 8] {
        let par = run(PipelineConfig { threads, ..base.clone() });
        for (i, (a, b)) in serial.iter().zip(&par).enumerate() {
            assert_frames_identical(a, b, &format!("drfc threads={threads} frame={i}"));
        }
    }

    // And the fanned-out stage graph still matches the frozen monolith
    // (which culls through the serial single-pass path) on this grid.
    assert_engines_identical(&scene, base, ViewCondition::Extreme, 3, 0);
}

#[test]
fn project_intersect_fanout_is_thread_invariant() {
    // The project stage's per-gaussian-chunk fan-out and the intersect
    // stage's two-phase tile binning + per-block working-set fan-out: a
    // dense scene under the extreme condition keeps every worker chunk
    // non-empty and moves the visible set (and therefore the bins and
    // block working sets) every frame. The splat list, bins, and working
    // sets feed *every* downstream stat — sort cycles, SRAM reuse, blend
    // pairs, DRAM traffic — so any partition leak shows up in the frame
    // results. Frame 0 renders numerically so the exact blend-pair path
    // crosses the fan-outs too.
    let scene = SynthParams::new(SceneKind::DynamicLarge, 6000).with_seed(29).generate();
    let base = PipelineConfig::paper(true).with_resolution(192, 108);
    let seq = trajectory(&scene, ViewCondition::Extreme, 3, 192, 108);
    let run = |config: PipelineConfig| -> Vec<FrameResult> {
        let mut p = FramePipeline::new(&scene, config);
        seq.iter()
            .enumerate()
            .map(|(i, (cam, t))| p.render_frame(cam, *t, i == 0))
            .collect()
    };

    let serial = run(PipelineConfig { threads: 1, ..base.clone() });
    assert!(
        serial.iter().all(|r| r.intersections > 0 && r.n_visible > 0),
        "the fan-outs must see real binning work"
    );
    for threads in [2, 8] {
        let par = run(PipelineConfig { threads, ..base.clone() });
        for (i, (a, b)) in serial.iter().zip(&par).enumerate() {
            assert_frames_identical(a, b, &format!("project/intersect threads={threads} frame={i}"));
        }
    }

    // And the fanned-out stage graph still matches the frozen monolith
    // (which projects and bins through the serial single-pass path).
    assert_engines_identical(&scene, base, ViewCondition::Extreme, 3, 3);
}

#[test]
fn steady_state_frames_reuse_all_scratch_capacity() {
    // Static trajectory: identical views, so from frame 2 on every pooled
    // buffer has reached its working size — the capacity signature must
    // freeze, i.e. zero scratch-vector allocations per frame.
    let scene = SynthParams::new(SceneKind::DynamicLarge, 5000).with_seed(3).generate();
    let config = PipelineConfig::paper(true).with_resolution(256, 144);
    // Frozen scene time as well as pose: the per-frame working sets are
    // exactly constant, so any capacity growth after warm-up is a real
    // steady-state allocation.
    let seq = Trajectory::new(ViewCondition::Static, 6)
        .with_scene(Vec3::new(0.0, 1.0, 0.0), 24.0)
        .with_time_span(0.3, 0.3)
        .generate(&template(256, 144));
    let mut p = FramePipeline::new(&scene, config);

    // Only frame 0 may grow the pools: with pose and scene time frozen,
    // every later frame re-fills the same working sets, so the acceptance
    // contract ("second and later frames allocate nothing") applies from
    // frame 1 on.
    p.render_frame(&seq[0].0, seq[0].1, false);
    let frozen = p.scratch_capacities();
    assert!(frozen.iter().sum::<usize>() > 0, "pools are in use");

    for (i, (cam, t)) in seq.iter().enumerate().skip(1) {
        let r = p.render_frame(cam, *t, false);
        assert!(r.n_visible > 0, "frame {i} renders real work");
        assert_eq!(
            p.scratch_capacities(),
            frozen,
            "frame {i}: a pooled scratch buffer reallocated in steady state"
        );
    }
}
