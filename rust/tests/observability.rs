//! Observability-layer contract (`obs::registry` + `obs::trace`):
//!
//! 1. **Ladder reference** — [`LatencyLadder::of`] matches a naive
//!    sort-then-nearest-rank reference at every rung, including the empty /
//!    single-sample / all-ties edge cases.
//! 2. **Registry determinism** — the `deterministic` section of a
//!    [`Registry`] assembled from a session run is byte-identical at
//!    threads 1/4/8 for every scheduling policy, static *and* dynamic
//!    serving, while the `host` section is free to differ.
//! 3. **Trace determinism** — the exported Chrome trace stream (frame /
//!    stage spans, per-channel DRAM spans, lifecycle instants — all in
//!    simulated ns) is bit-identical across thread counts per policy, for
//!    both the contended-batch path and join/leave session streams.
//! 4. **Trace well-formedness** — the export round-trips through the
//!    crate's JSON parser, carries process/thread metadata, and every
//!    viewer track nests monotonically: stages inside frames, consecutive
//!    frames laid out without overlap.

use gaucim::camera::ViewCondition;
use gaucim::coordinator::{
    RenderServer, SchedPolicy, SessionScript, SessionSpec, ViewerSpec,
};
use gaucim::obs::{percentile, sink, Component, LatencyLadder, Registry};
use gaucim::pipeline::PipelineConfig;
use gaucim::scene::synth::{SceneKind, SynthParams};
use gaucim::util::json::{parse, Json};

fn server(threads: usize, dynamic: bool) -> RenderServer {
    let scene = SynthParams::new(SceneKind::DynamicLarge, 1500).with_seed(21).generate();
    let mut config =
        PipelineConfig::paper(true).with_resolution(128, 72).with_threads(threads);
    config.dynamic_updates = dynamic;
    RenderServer::new(scene, config)
}

fn join_leave_script() -> SessionScript {
    SessionScript::new()
        .join_at(0, SessionSpec::stream(ViewCondition::Average, 4).with_deadline_fps(120.0))
        .join_at(
            0,
            SessionSpec::stream(ViewCondition::Static, 4)
                .with_deadline_fps(60.0)
                .with_weight(2.0),
        )
        .join_at(2, SessionSpec::stream(ViewCondition::Extreme, 2).with_start(2))
        .leave_at(3, 0)
}

// ---------------------------------------------------------------- ladder --

/// Naive reference: sort a copy, then nearest-rank per rung.
fn naive_ladder(samples: &[f64]) -> LatencyLadder {
    if samples.is_empty() {
        return LatencyLadder::default();
    }
    let mut v = samples.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = |p: f64| v[(((p / 100.0) * (v.len() as f64 - 1.0)).round() as usize).min(v.len() - 1)];
    LatencyLadder {
        count: v.len() as u64,
        min: v[0],
        mean: v.iter().sum::<f64>() / v.len() as f64,
        p50: rank(50.0),
        p75: rank(75.0),
        p90: rank(90.0),
        p95: rank(95.0),
        p99: rank(99.0),
        p99_9: rank(99.9),
        max: v[v.len() - 1],
    }
}

#[test]
fn ladder_matches_naive_reference_on_edge_cases() {
    // Deterministic pseudo-random population (LCG — no host entropy).
    let mut x = 12345u64;
    let mut noisy = Vec::new();
    for _ in 0..997 {
        x = x.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
        noisy.push((x >> 33) as f64 / 1e6);
    }
    let cases: Vec<Vec<f64>> = vec![
        vec![],
        vec![42.0],
        vec![3.0; 64],
        vec![2.0, 1.0],
        (0..100).rev().map(|i| i as f64).collect(),
        noisy,
    ];
    for samples in &cases {
        let ladder = LatencyLadder::of(samples);
        let reference = naive_ladder(samples);
        assert_eq!(ladder, reference, "ladder diverged on {} samples", samples.len());
        // The shared percentile helper agrees with the ladder rungs.
        assert_eq!(ladder.p50, percentile(samples, 50.0));
        assert_eq!(ladder.p99, percentile(samples, 99.0));
    }
}

// -------------------------------------------------------------- registry --

#[test]
fn registry_deterministic_section_is_byte_identical_across_threads() {
    let script = join_leave_script();
    for dynamic in [false, true] {
        for policy in SchedPolicy::ALL {
            let registry_at = |threads: usize| {
                let rep = server(threads, dynamic).render_sessions(&script, policy);
                let mut metrics = Registry::new();
                metrics.deterministic =
                    Component::new().set("sessions", rep.component());
                metrics.host = Component::new().set("wall_s", rep.wall_s);
                metrics.to_json()
            };
            let baseline = registry_at(1);
            let baseline_det = baseline.get("deterministic").expect("section").pretty();
            assert_eq!(baseline.get("schema").unwrap().as_usize(), Some(1));
            for threads in [4, 8] {
                let other = registry_at(threads);
                assert_eq!(
                    baseline_det,
                    other.get("deterministic").expect("section").pretty(),
                    "{} (dynamic={dynamic}) deterministic section diverged at \
                     threads={threads}",
                    policy.label()
                );
            }
        }
    }
}

// ----------------------------------------------------------------- trace --

fn session_trace(threads: usize, policy: SchedPolicy) -> String {
    let mut server = server(threads, false);
    let trace = sink();
    server.set_tracer(trace.clone());
    server.render_sessions(&join_leave_script(), policy);
    let chrome = trace.lock().unwrap().chrome_json().pretty();
    chrome
}

#[test]
fn session_trace_stream_is_bit_identical_across_threads_per_policy() {
    for policy in SchedPolicy::ALL {
        let baseline = session_trace(1, policy);
        // The stream is substantive: frame spans, DRAM channel spans, and
        // lifecycle instants all present.
        assert!(baseline.contains("\"frame 0\""), "{}: no frame spans", policy.label());
        assert!(baseline.contains("\"dram\""), "{}: no DRAM spans", policy.label());
        assert!(baseline.contains("\"join\""), "{}: no join instants", policy.label());
        assert!(baseline.contains("\"leave\""), "{}: no leave instants", policy.label());
        for threads in [4, 8] {
            assert_eq!(
                baseline,
                session_trace(threads, policy),
                "{} trace diverged at threads={threads}",
                policy.label()
            );
        }
    }
}

#[test]
fn contended_batch_trace_is_bit_identical_across_threads() {
    let specs = [
        ViewerSpec::perf(ViewCondition::Average, 3),
        ViewerSpec::perf(ViewCondition::Static, 2),
        ViewerSpec::perf(ViewCondition::Extreme, 3),
    ];
    let run = |threads: usize| {
        let mut server = server(threads, false);
        let trace = sink();
        server.set_tracer(trace.clone());
        server.render_batch_contended(&specs);
        let chrome = trace.lock().unwrap().chrome_json().pretty();
        chrome
    };
    // threads=1 drives the lockstep path, threads>1 the two-phase
    // trace/replay path — both must record the very same event stream.
    let baseline = run(1);
    assert!(baseline.contains("\"contended-batch\""));
    for threads in [4, 8] {
        assert_eq!(baseline, run(threads), "batch trace diverged at threads={threads}");
    }
}

// ------------------------------------------------------- well-formedness --

#[test]
fn chrome_trace_parses_with_monotone_span_nesting() {
    let text = session_trace(1, SchedPolicy::RoundRobin);
    let doc = parse(&text).expect("trace must be valid JSON");
    let events = match doc.get("traceEvents") {
        Some(Json::Arr(v)) => v,
        other => panic!("traceEvents missing: {other:?}"),
    };
    assert!(
        events.iter().any(|e| {
            e.get("name").and_then(Json::as_str) == Some("process_name")
        }),
        "process metadata missing"
    );
    assert!(
        events.iter().any(|e| {
            e.get("name").and_then(Json::as_str) == Some("thread_name")
        }),
        "thread metadata missing"
    );

    // Per viewer track, replay the complete spans through a nesting stack:
    // a span either nests inside the still-open span above it or starts at
    // (or after) that span's end. Frames therefore enclose their stages and
    // consecutive frames never overlap.
    let mut tracks: std::collections::BTreeMap<(u64, u64), Vec<(f64, f64)>> =
        std::collections::BTreeMap::new();
    let mut spans = 0usize;
    for e in events {
        if e.get("ph").and_then(Json::as_str) != Some("X") {
            continue;
        }
        spans += 1;
        let pid = e.get("pid").and_then(Json::as_usize).unwrap() as u64;
        let tid = e.get("tid").and_then(Json::as_usize).unwrap() as u64;
        let ts = e.get("ts").and_then(Json::as_f64).unwrap();
        let dur = e.get("dur").and_then(Json::as_f64).unwrap();
        assert!(ts >= 0.0 && dur >= 0.0, "negative time in span {e:?}");
        if (10..1000).contains(&tid) {
            tracks.entry((pid, tid)).or_default().push((ts, ts + dur));
        }
    }
    assert!(spans > 0, "no complete spans recorded");
    assert!(!tracks.is_empty(), "no viewer tracks recorded");
    for ((pid, tid), spans) in &tracks {
        let mut stack: Vec<(f64, f64)> = Vec::new();
        for &(start, end) in spans {
            let eps = 1e-6 * (1.0 + end.abs());
            while let Some(&(_, top_end)) = stack.last() {
                if top_end <= start + eps {
                    stack.pop();
                } else {
                    break;
                }
            }
            if let Some(&(top_start, top_end)) = stack.last() {
                assert!(
                    start + eps >= top_start && end <= top_end + eps,
                    "span [{start}, {end}] escapes enclosing [{top_start}, {top_end}] \
                     on pid={pid} tid={tid}"
                );
            }
            stack.push((start, end));
        }
    }
}
