//! Event-queue memory subsystem determinism suite.
//!
//! 1. **Oracle bit-identity**: the event-queue `MemorySystem` configured
//!    with `channels = 1, outstanding = 1, shards = 1` must reproduce the
//!    synchronous `SyncDramModel` statistics **bit-for-bit** over mixed
//!    request streams (short, long/fast-path, scattered, row-boundary
//!    sizes) — the freeze-the-monolith pattern applied to the memory
//!    layer. Pipeline-level: a cold frame's preprocess traffic matches
//!    exactly between the two backends.
//! 2. **Contention**: viewers sharing one `MemorySystem` transfer exactly
//!    the bytes/bursts they transfer in isolation (addresses are
//!    timing-independent) but report strictly higher per-viewer `busy_ns`
//!    — queueing behind each other's traffic is visible, fairly spread by
//!    the rotating lockstep order.
//! 3. **Sharding**: a conventional full-scene sweep split over 4 channel
//!    groups overlaps across them (shorter busy time, identical bursts).

use gaucim::camera::{Camera, ViewCondition};
use gaucim::coordinator::{RenderServer, ViewerSpec};
use gaucim::math::Vec3;
use gaucim::memory::{
    DramConfig, MemMode, MemSimConfig, MemStage, MemorySystem, ShardMap, SyncDramModel,
};
use gaucim::pipeline::{FramePipeline, PipelineConfig};
use gaucim::scene::synth::{SceneKind, SynthParams};

/// A mixed request stream: contiguous sweeps either side of the analytic
/// fast-path boundary, partial bursts, row-stride scatter, revisits.
fn mixed_stream(cfg: &DramConfig) -> Vec<(u64, u64)> {
    let bpr = cfg.row_bytes / cfg.burst_bytes;
    let threshold_bytes = 4 * bpr * cfg.burst_bytes;
    let mut reqs: Vec<(u64, u64)> = vec![
        (0, 1),                          // single partial burst
        (10, 8),                         // inside one burst
        (30, 8),                         // straddles a burst boundary
        (0, cfg.row_bytes),              // exactly one row
        (64, threshold_bytes - 64),      // just under the fast path
        (0, threshold_bytes),            // at the boundary (per-burst walk)
        (0, threshold_bytes + cfg.burst_bytes), // just over (fast path)
        (1 << 16, 1 << 20),              // deep fast path
        (0, 4096),                       // revisit rows left open
    ];
    // Row-stride scatter (mostly misses) + revisits (hits).
    for i in 0..64u64 {
        reqs.push((i * cfg.row_bytes * 3 + 128, 32));
    }
    for i in 0..16u64 {
        reqs.push((i * cfg.row_bytes * 3 + 160, 32));
    }
    reqs
}

#[test]
fn event_queue_oracle_point_matches_sync_model_bit_for_bit() {
    let sim = MemSimConfig::oracle_point();
    let dram = sim.dram;

    let mut sync = SyncDramModel::new(dram);
    let mut sys = MemorySystem::new(sim, ShardMap::single(u64::MAX));
    let port = sys.register_port();

    for &(addr, bytes) in &mixed_stream(&dram) {
        sync.read(addr, bytes);
        sys.read(port, MemStage::Preprocess, addr, bytes);
    }

    let expect = sync.stats();
    let got = sys.port_stage_stats(port, MemStage::Preprocess);
    // Bit-for-bit: u64 counters and f64 energy/busy all exactly equal,
    // contention fields exactly zero (as the synchronous model reports).
    assert_eq!(got, expect, "event queue at the oracle point diverged");
    assert_eq!(got.wait_ns, 0.0);
    assert_eq!(got.stalls, 0);
}

fn template(w: usize, h: usize) -> Camera {
    let mut c = Camera::look_at(
        Vec3::new(0.0, 4.0, 20.0),
        Vec3::ZERO,
        Vec3::new(0.0, 1.0, 0.0),
        60f32.to_radians(),
        w as f32 / h as f32,
        0.1,
        200.0,
    );
    c.set_resolution(w, h);
    c
}

#[test]
fn pipeline_preprocess_traffic_matches_across_backends_on_cold_frame() {
    let scene = SynthParams::new(SceneKind::DynamicLarge, 4000).with_seed(23).generate();
    let base = PipelineConfig::paper(true).with_resolution(192, 108);
    let cam = template(192, 108);

    let sync_cfg = PipelineConfig {
        mem: MemSimConfig {
            mode: MemMode::Sync,
            dram: DramConfig { channels: 1, ..DramConfig::default() },
            outstanding: 1,
            shards: 1,
        },
        ..base.clone()
    };
    let eq_cfg = PipelineConfig { mem: MemSimConfig::oracle_point(), ..base };

    let mut p_sync = FramePipeline::new(&scene, sync_cfg);
    let mut p_eq = FramePipeline::new(&scene, eq_cfg);
    let r_sync = p_sync.render_frame(&cam, 0.3, false);
    let r_eq = p_eq.render_frame(&cam, 0.3, false);

    // Cold frame, cull issues first: the event-queue preprocess stream is
    // bit-identical to the synchronous model.
    assert_eq!(
        r_eq.traffic.preprocess_dram, r_sync.traffic.preprocess_dram,
        "preprocess DRAM stats diverged across backends"
    );
    // Blend channel state differs by design (shared channels see the cull
    // stream's open rows; the sync blend model is private and cold), but
    // the transfer counts are timing-independent.
    assert_eq!(r_eq.traffic.blend_dram.bytes, r_sync.traffic.blend_dram.bytes);
    assert_eq!(r_eq.traffic.blend_dram.bursts, r_sync.traffic.blend_dram.bursts);
    assert_eq!(r_eq.traffic.blend_sram, r_sync.traffic.blend_sram);
    assert_eq!(r_eq.n_visible, r_sync.n_visible);
}

#[test]
fn contended_viewers_transfer_identical_bytes_but_strictly_more_busy_time() {
    let scene = SynthParams::new(SceneKind::DynamicLarge, 3000).with_seed(31).generate();
    let config = PipelineConfig::paper(true).with_resolution(160, 96);
    let frames = 3;
    let server = RenderServer::new(scene, config.clone());
    let specs = [
        ViewerSpec::perf(ViewCondition::Average, frames),
        ViewerSpec::perf(ViewCondition::Static, frames),
    ];

    // Sequential baseline (synchronous private models): the byte/burst
    // ground truth.
    let sequential: Vec<_> = specs
        .iter()
        .enumerate()
        .map(|(i, s)| server.render_viewer(i, s))
        .collect();

    // Isolated event-queue runs: same trajectories, same backend, private
    // memory systems — the busy-time baseline without cross-viewer
    // contention.
    let mut eq_cfg = config.clone();
    eq_cfg.mem.mode = MemMode::EventQueue;
    let isolated_busy: Vec<f64> = specs
        .iter()
        .map(|spec| {
            let mut pipeline = server.shared.pipeline(eq_cfg.clone());
            let mut busy = 0.0;
            for (cam, t) in server.trajectory(spec) {
                let r = pipeline.render_frame(&cam, t, false);
                busy += r.traffic.preprocess_dram.busy_ns + r.traffic.blend_dram.busy_ns;
            }
            busy
        })
        .collect();

    // Contended batch: one shared memory system, lockstep rounds.
    let batch = server.render_batch_contended(&specs);
    let mem = batch.contended_mem.as_ref().expect("contended roll-up");

    for (i, (seq_rep, par_rep)) in sequential.iter().zip(&batch.viewers).enumerate() {
        // Per-viewer transfer counts identical to the sequential baseline
        // (addresses are timing-independent; u64 sums divide identically).
        assert_eq!(
            seq_rep.avg_dram_accesses, par_rep.avg_dram_accesses,
            "viewer {i}: burst count changed under contention"
        );
        assert_eq!(
            seq_rep.avg_dram_bytes, par_rep.avg_dram_bytes,
            "viewer {i}: byte count changed under contention"
        );
        assert_eq!(seq_rep.avg_visible, par_rep.avg_visible);
        assert_eq!(seq_rep.avg_sort_cycles, par_rep.avg_sort_cycles);
    }

    for (i, row) in mem.viewers.iter().enumerate() {
        assert!(
            row.total_busy_ns() > isolated_busy[i],
            "viewer {i}: contended busy {} must exceed isolated busy {}",
            row.total_busy_ns(),
            isolated_busy[i]
        );
        assert!(row.total_wait_ns() > 0.0, "viewer {i}: no contention wait recorded");
    }
}

#[test]
fn sharded_conventional_sweep_overlaps_channel_groups() {
    let scene = SynthParams::new(SceneKind::DynamicLarge, 6000).with_seed(9).generate();
    let cam = template(160, 96);
    let base = PipelineConfig {
        use_drfc: false, // conventional full-scene sweep
        ..PipelineConfig::paper(true).with_resolution(160, 96)
    };
    let mk = |shards: usize| PipelineConfig {
        mem: MemSimConfig {
            mode: MemMode::EventQueue,
            dram: DramConfig { channels: 1, ..DramConfig::default() },
            outstanding: 8,
            shards,
        },
        ..base.clone()
    };

    let mut p1 = FramePipeline::new(&scene, mk(1));
    let mut p4 = FramePipeline::new(&scene, mk(4));
    let r1 = p1.render_frame(&cam, 0.2, false);
    let r4 = p4.render_frame(&cam, 0.2, false);

    // Same data moved (row-aligned shard splits never split a burst)...
    assert_eq!(r1.traffic.preprocess_dram.bursts, r4.traffic.preprocess_dram.bursts);
    assert_eq!(r1.traffic.preprocess_dram.bytes, r4.traffic.preprocess_dram.bytes);
    // ...but four channel groups serve the sweep mostly in parallel.
    assert!(
        r4.traffic.preprocess_dram.busy_ns < 0.5 * r1.traffic.preprocess_dram.busy_ns,
        "sharded sweep {} vs single group {}",
        r4.traffic.preprocess_dram.busy_ns,
        r1.traffic.preprocess_dram.busy_ns
    );
}
