//! Scale-harness integration contract (`coordinator::loadgen` + the
//! indexed scheduler hot path):
//!
//! 1. **Generated workloads are schedulable** — a flash-crowd preset
//!    script streams to completion under an admission budget, reports
//!    byte-identically between the indexed and full-sort reference
//!    bookkeeping, across host thread counts, and for every policy.
//! 2. **5k-event churn** — a 5000-event one-frame-per-session script
//!    (the mostly-idle 10k-session shape, scaled down) validates in one
//!    pass and streams every session exactly once with detached-state
//!    collection off.
//! 3. **Issue-order property** — over randomized scripts (random joins,
//!    leaves, weights, deadlines, budgets), the indexed DWFQ/EDF keyed
//!    heaps emit the exact issue order of the full-sort reference: the
//!    whole report is byte-identical, policy by policy.

use gaucim::camera::ViewCondition;
use gaucim::coordinator::session::DEFAULT_STREAM_FPS;
use gaucim::coordinator::{
    LoadGen, LoadPreset, RenderServer, SchedPolicy, SessionScript, SessionSpec,
};
use gaucim::pipeline::PipelineConfig;
use gaucim::scene::synth::{SceneKind, SynthParams};
use gaucim::util::Rng;

fn server(threads: usize) -> RenderServer {
    let scene = SynthParams::new(SceneKind::DynamicLarge, 800).with_seed(17).generate();
    let config = PipelineConfig::paper(true).with_resolution(96, 54).with_threads(threads);
    RenderServer::new(scene, config)
}

/// The admission budget the scale harness derives from a preset's
/// `target_concurrency` (the scheduler's own cold-stream demand estimate).
fn budget_gbps(server: &RenderServer, lg: &LoadGen) -> Option<f64> {
    let fallback_demand =
        server.shared.prep.layout.total_span_bytes() as f64 / 10.0 * DEFAULT_STREAM_FPS;
    lg.target_concurrency.map(|tc| tc as f64 * fallback_demand / 1e9)
}

#[test]
fn flash_crowd_preset_streams_identically_across_impls_and_threads() {
    let mut lg = LoadGen::preset(LoadPreset::Flash, 40, 9);
    lg.dwell_mean_frames = 2;
    let script = lg.generate();
    assert_eq!(script.n_sessions(), 40);
    let budget = budget_gbps(&server(1), &lg);

    // Byte-identity across bookkeeping implementations and thread counts
    // under DWFQ (the keyed-heap policy the harness ladders).
    let reference = {
        let server = server(1);
        let mut sched = server.sessions(SchedPolicy::Dwfq).with_reference_order();
        if let Some(g) = budget {
            sched = sched.dram_budget_gbps(g);
        }
        sched.run(&script)
    };
    for threads in [1, 4] {
        let server = server(threads);
        let mut sched = server.sessions(SchedPolicy::Dwfq).discard_detached();
        if let Some(g) = budget {
            sched = sched.dram_budget_gbps(g);
        }
        let rep = sched.run(&script);
        assert_eq!(
            reference.simulated_projection(),
            rep.simulated_projection(),
            "indexed flash-crowd stream diverged at threads={threads}"
        );
    }

    // The burst oversubscribes the budget, so admission actually defers.
    assert!(
        reference.admission_wait_rounds.p99 > 0.0,
        "flash-crowd preset must exercise the admission queue"
    );
    // Every policy agrees between implementations on the same workload.
    for policy in SchedPolicy::ALL {
        let server = server(1);
        let a = server.sessions(policy).run(&script).simulated_projection();
        let b = server.sessions(policy).with_reference_order().run(&script).simulated_projection();
        assert_eq!(a, b, "{} diverged between bookkeeping implementations", policy.label());
    }
}

#[test]
fn five_thousand_event_churn_script_streams_every_session_once() {
    // 2500 sessions × (join + leave) = 5000 events, one frame each,
    // staggered so the live set stays tiny — the mostly-idle churn shape.
    // Validation is one pass over the events; discard_detached keeps the
    // run's memory bounded by the (tiny) peak concurrency.
    let n = 2500;
    let mut script = SessionScript::new();
    for i in 0..n {
        script = script
            .join_at(i, SessionSpec::stream(ViewCondition::Static, 1))
            .leave_at(i + 2, i);
    }
    let server = server(1);
    let rep = server.sessions(SchedPolicy::RoundRobin).discard_detached().run(&script);
    assert_eq!(rep.total_frames, n);
    assert_eq!(rep.sessions.len(), n);
    assert!(rep.sessions.iter().all(|s| s.frames == 1));
    assert!(rep.peak_live <= 3, "staggered script must keep the live set tiny");
    assert!(rep.rounds >= n, "staggered joins stretch the stream");
}

/// A randomized-but-valid join/leave script: random join rounds, dwell
/// lengths, deadlines, weights, and optional leaves (always strictly
/// after the join).
fn random_script(rng: &mut Rng) -> SessionScript {
    let n = rng.range_usize(2, 6);
    let mut script = SessionScript::new();
    let mut joins = Vec::new();
    for _ in 0..n {
        let join = rng.below(3);
        let frames = rng.range_usize(1, 3);
        let mut spec = SessionSpec::stream(
            [ViewCondition::Static, ViewCondition::Average, ViewCondition::Extreme]
                [rng.below(3)],
            frames,
        );
        if join > 0 && rng.chance(0.5) {
            spec = spec.with_start(join);
        }
        if rng.chance(0.6) {
            spec = spec.with_deadline_fps([30.0, 60.0, 120.0][rng.below(3)]);
        }
        if rng.chance(0.3) {
            spec = spec.with_weight(2.0);
        }
        script = script.join_at(join, spec);
        joins.push((join, frames));
    }
    for (id, &(join, frames)) in joins.iter().enumerate() {
        if rng.chance(0.5) {
            script = script.leave_at(join + 1 + rng.below(frames + 2), id);
        }
    }
    script
}

#[test]
fn randomized_scripts_issue_in_exact_reference_order() {
    let mut rng = Rng::new(0x5CA1E);
    for case in 0..5 {
        let mut case_rng = rng.fork(case);
        let script = random_script(&mut case_rng);
        let server = server(1);
        let fallback_demand =
            server.shared.prep.layout.total_span_bytes() as f64 / 10.0 * DEFAULT_STREAM_FPS;
        let budget =
            if case_rng.chance(0.4) { Some(fallback_demand * 1.5 / 1e9) } else { None };
        for policy in SchedPolicy::ALL {
            let run = |reference: bool| {
                let mut sched = server.sessions(policy);
                if reference {
                    sched = sched.with_reference_order();
                }
                if let Some(g) = budget {
                    sched = sched.dram_budget_gbps(g);
                }
                sched.run(&script).simulated_projection()
            };
            assert_eq!(
                run(false),
                run(true),
                "case {case}: indexed {} diverged from the full-sort reference\nscript: {}",
                policy.label(),
                script.to_json().pretty()
            );
        }
    }
}
