//! Render-backend bit-identity: the lane-batched blend datapath
//! (`RenderBackend::Lanes`) must produce byte-identical pixels *and*
//! identical NMC integer statistics to the scalar per-pixel loop
//! (`RenderBackend::Scalar`) — serial and tile-parallel, at any thread
//! count, at any resolution (ragged tile tails included). The lane
//! kernel earns this by performing the exact scalar f32 op sequence per
//! lane and masking skipped/saturated lanes with selects; these tests
//! are the contract's enforcement.

use gaucim::camera::Camera;
use gaucim::coordinator::App;
use gaucim::dcim::{ExpLut, NmcAccumulator};
use gaucim::math::Vec3;
use gaucim::pipeline::WorkerPool;
use gaucim::render::{HwRenderer, ReferenceRenderer, RenderBackend};
use gaucim::scene::synth::{SceneKind, SynthParams};

fn cam(w: usize, h: usize, dist: f32) -> Camera {
    let mut c = Camera::look_at(
        Vec3::new(0.0, 3.0, dist),
        Vec3::ZERO,
        Vec3::new(0.0, 1.0, 0.0),
        60f32.to_radians(),
        w as f32 / h as f32,
        0.1,
        200.0,
    );
    c.set_resolution(w, h);
    c
}

/// Pixels AND NmcStats: lanes == scalar, serial and parallel at
/// threads 1/4/8 — the headline acceptance test.
#[test]
fn render_backend_is_bit_identical() {
    let scene = SynthParams::new(SceneKind::StaticLarge, 3000).generate();
    let c = cam(160, 96, 25.0);
    let scalar = HwRenderer::new(160, 96).with_backend(RenderBackend::Scalar);
    let lanes = HwRenderer::new(160, 96).with_backend(RenderBackend::Lanes);
    let splats = scalar.project_all(&scene, &c, 0.0);
    let order: Vec<usize> = (0..scalar.grid.n_tiles()).collect();

    let mut nmc_s = NmcAccumulator::new();
    let img_s = scalar.render_splats_ordered(&splats, &order, &mut nmc_s);
    let mut nmc_l = NmcAccumulator::new();
    let img_l = lanes.render_splats_ordered(&splats, &order, &mut nmc_l);
    assert_eq!(img_s, img_l, "serial pixels diverged between backends");
    assert_eq!(nmc_s.stats(), nmc_l.stats(), "serial NMC stats diverged");

    for threads in [1, 4, 8] {
        let pool = WorkerPool::new(threads);
        for r in [&scalar, &lanes] {
            let mut nmc = NmcAccumulator::new();
            let img = r.render_splats_ordered_par(&splats, &order, &mut nmc, &pool);
            assert_eq!(
                img_s, img,
                "parallel pixels diverged ({:?} backend, {threads} threads)",
                r.backend
            );
            assert_eq!(
                nmc_s.stats(),
                nmc.stats(),
                "parallel NMC stats diverged ({:?} backend, {threads} threads)",
                r.backend
            );
        }
    }
}

/// 97×53 leaves 1-pixel-wide and 5-pixel-tall edge tiles — every row of
/// every edge tile exercises the scalar ragged tail next to full 8-wide
/// spans in interior tiles.
#[test]
fn odd_resolution_ragged_tail_is_bit_identical() {
    let scene = SynthParams::new(SceneKind::StaticLarge, 1500).generate();
    let c = cam(97, 53, 25.0);

    let scalar = HwRenderer::new(97, 53).with_backend(RenderBackend::Scalar);
    let lanes = HwRenderer::new(97, 53).with_backend(RenderBackend::Lanes);
    let splats = scalar.project_all(&scene, &c, 0.0);
    let order: Vec<usize> = (0..scalar.grid.n_tiles()).collect();
    let mut nmc_s = NmcAccumulator::new();
    let mut nmc_l = NmcAccumulator::new();
    let img_s = scalar.render_splats_ordered(&splats, &order, &mut nmc_s);
    let img_l = lanes.render_splats_ordered(&splats, &order, &mut nmc_l);
    assert_eq!(img_s, img_l, "hw ragged-tail pixels diverged");
    assert_eq!(nmc_s.stats(), nmc_l.stats(), "hw ragged-tail NMC stats diverged");

    let ref_s = ReferenceRenderer::new(97, 53).with_backend(RenderBackend::Scalar);
    let ref_l = ReferenceRenderer::new(97, 53).with_backend(RenderBackend::Lanes);
    assert_eq!(
        ref_s.render(&scene, &c, 0.0),
        ref_l.render(&scene, &c, 0.0),
        "reference ragged-tail pixels diverged"
    );
}

/// The reference renderer's lane kernel (exact `exp()`, no LUT) must
/// also be pixel-exact against its scalar loop at an even resolution.
#[test]
fn reference_backend_is_bit_identical() {
    let scene = SynthParams::new(SceneKind::StaticLarge, 3000).generate();
    let c = cam(160, 96, 25.0);
    let img_s = ReferenceRenderer::new(160, 96)
        .with_backend(RenderBackend::Scalar)
        .render(&scene, &c, 0.0);
    let img_l = ReferenceRenderer::new(160, 96)
        .with_backend(RenderBackend::Lanes)
        .render(&scene, &c, 0.0);
    assert_eq!(img_s, img_l, "reference pixels diverged between backends");
}

/// `ExpLut::exp2_lanes` must match the scalar `exp2` bit-for-bit on
/// every lane: a dense sweep over the interesting domain plus the edge
/// cases (±∞, NaN, ±0, subnormals, extremes of the f32 range).
#[test]
fn exp2_lanes_matches_scalar_bitwise() {
    let lut = ExpLut::paper();
    let mut inputs: Vec<f32> = Vec::new();
    // Dense sweep: the blend path feeds roughly [-21, 0] (EXP_CUTOFF
    // times LOG2_E), but sweep far past it on both sides.
    let (lo, hi, steps) = (-160.0f32, 40.0f32, 16_000usize);
    for i in 0..=steps {
        inputs.push(lo + (hi - lo) * (i as f32 / steps as f32));
    }
    // Edge cases: non-finite, signed zero, subnormal, range extremes.
    inputs.extend([
        f32::INFINITY,
        f32::NEG_INFINITY,
        f32::NAN,
        0.0,
        -0.0,
        f32::MIN_POSITIVE,
        -f32::MIN_POSITIVE,
        1e-41,  // positive subnormal
        -1e-41, // negative subnormal
        f32::MAX,
        f32::MIN,
        -149.5, // deep into the subnormal *result* range
        127.5,  // overflows to +inf through libm_exp2i
    ]);
    // Pad to a multiple of 8 so chunks_exact covers everything.
    while inputs.len() % 8 != 0 {
        inputs.push(0.0);
    }
    for chunk in inputs.chunks_exact(8) {
        let x: [f32; 8] = chunk.try_into().unwrap();
        let got = lut.exp2_lanes(x);
        for i in 0..8 {
            let want = lut.exp2(x[i]);
            assert_eq!(
                want.to_bits(),
                got[i].to_bits(),
                "exp2_lanes({}) = {} != scalar {}",
                x[i],
                got[i],
                want
            );
        }
    }
}

/// Whole-pipeline gate: the same experiment at scalar vs lanes produces
/// byte-identical frames and bit-identical PSNR through `App` — the
/// config seam (`PipelineConfig::render_backend`) end to end.
#[test]
fn pipeline_report_is_backend_invariant() {
    let mut app = App::new(SceneKind::StaticLarge, 2000, 42);
    app.config = app.config.clone().with_resolution(192, 108);

    app.config = app.config.clone().with_render_backend(RenderBackend::Scalar);
    let (img_s, rep_s) = app.render_one(0.5);
    app.config = app.config.clone().with_render_backend(RenderBackend::Lanes);
    let (img_l, rep_l) = app.render_one(0.5);
    assert_eq!(img_s, img_l, "pipeline frames diverged between backends");
    assert_eq!(
        rep_s.psnr_db.to_bits(),
        rep_l.psnr_db.to_bits(),
        "pipeline PSNR diverged between backends"
    );
}
