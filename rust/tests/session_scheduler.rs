//! Session-scheduler lifecycle contract:
//!
//! 1. **Round-robin bit-compatibility** — a no-join/no-leave script under
//!    `SchedPolicy::RoundRobin` reproduces `render_batch_contended`'s
//!    `ContendedMemReport` (and per-viewer reports) bit-for-bit, at any
//!    host thread count.
//! 2. **Script determinism** — join/leave scripts replay identically at
//!    threads = 1/2/8 (the simulated projection is the comparison surface).
//! 3. **Mid-stream joins** — a session joining at frame k with
//!    `start_frame = k` produces frames identical to a fresh viewer whose
//!    trajectory starts at k (timing-independent stats compared against an
//!    isolated run).
//! 4. **Policies** — DWFQ and EDF yield schedules distinct from
//!    round-robin, each deterministic under replay.
//! 5. **Retained state** — a joiner warm-started from a departed session's
//!    AII intervals skips the phase-1 scan its cold twin pays for.
//! 6. **Admission control** — a tiny DRAM budget defers the second join
//!    but stays work-conserving (every session still streams to
//!    completion).
//! 7. **Round engine** — the full `SessionBatchReport` JSON is
//!    byte-identical at threads 1/4/8 for all three policies (lockstep vs
//!    two-phase trace/replay).
//! 8. **Cross-run persistence** — `take_detached` / `seed_detached` +
//!    `SessionSpec::resume_from` continue a departed stream bit-identically
//!    in a later scheduler run, at any thread count.
//! 9. **Indexed bookkeeping** — the indexed hot path (event index, linked
//!    ring, keyed heaps) is byte-identical to the full-sort reference for
//!    every policy and thread count; `discard_detached` frees departed
//!    working sets without changing a single statistic; script validation
//!    is one pass (a 5000-event duplicate leave is caught before any
//!    frame renders).

use gaucim::camera::ViewCondition;
use gaucim::coordinator::{
    RenderServer, SchedPolicy, SessionScript, SessionSpec, ViewerSpec,
};
use gaucim::memory::MemMode;
use gaucim::pipeline::PipelineConfig;
use gaucim::scene::synth::{SceneKind, SynthParams};

fn server(threads: usize) -> RenderServer {
    let scene = SynthParams::new(SceneKind::DynamicLarge, 1500).with_seed(21).generate();
    let config =
        PipelineConfig::paper(true).with_resolution(128, 72).with_threads(threads);
    RenderServer::new(scene, config)
}

#[test]
fn round_robin_static_script_matches_contended_batch_bit_for_bit() {
    // Uneven frame counts exercise the rotation-skip path; one viewer
    // renders numerically so PSNR scoring is covered too.
    let specs = [
        ViewerSpec { condition: ViewCondition::Average, frames: 3, psnr_every: 2 },
        ViewerSpec::perf(ViewCondition::Static, 2),
        ViewerSpec::perf(ViewCondition::Extreme, 3),
    ];
    for threads in [1, 4, 8] {
        let server = server(threads);
        let batch = server.render_batch_contended(&specs);
        let script = SessionScript::from_specs(&specs);
        let sessions = server.render_sessions(&script, SchedPolicy::RoundRobin);

        let batch_mem = batch.contended_mem.as_ref().expect("contended roll-up");
        assert_eq!(
            batch_mem.to_json().pretty(),
            sessions.contended.to_json().pretty(),
            "ContendedMemReport diverged at threads={threads}"
        );
        assert_eq!(batch.viewers.len(), sessions.sessions.len());
        for (b, s) in batch.viewers.iter().zip(&sessions.sessions) {
            assert_eq!(
                b.to_json().pretty(),
                s.seq.to_json().pretty(),
                "per-viewer report diverged at threads={threads}"
            );
        }
        assert_eq!(sessions.rounds, 3, "rounds = max frame count");
        assert_eq!(sessions.total_frames, 8);
        assert_eq!(sessions.policy.label(), "round_robin");
    }
}

fn join_leave_script() -> SessionScript {
    SessionScript::new()
        .join_at(0, SessionSpec::stream(ViewCondition::Average, 5).with_deadline_fps(120.0))
        .join_at(
            0,
            SessionSpec::stream(ViewCondition::Static, 5)
                .with_deadline_fps(60.0)
                .with_weight(2.0),
        )
        .join_at(
            2,
            SessionSpec::stream(ViewCondition::Extreme, 3)
                .with_start(2)
                .with_deadline_fps(90.0),
        )
        .leave_at(4, 0)
}

#[test]
fn join_leave_script_replays_identically_at_any_thread_count() {
    let script = join_leave_script();
    let run = |threads: usize| {
        server(threads).render_sessions(&script, SchedPolicy::Edf).simulated_projection()
    };
    let baseline = run(1);
    for threads in [2, 8] {
        assert_eq!(baseline, run(threads), "EDF stream diverged at threads={threads}");
    }
}

#[test]
fn every_policy_is_byte_identical_across_thread_counts() {
    // The round-engine acceptance gate: the full SessionBatchReport JSON —
    // per-session reports, latency percentiles, the contended roll-up —
    // must be byte-identical at threads 1/4/8 (lockstep vs two-phase
    // trace/replay) for all three policies over a join/leave stream.
    let script = join_leave_script();
    for policy in SchedPolicy::ALL {
        let baseline = server(1).render_sessions(&script, policy).simulated_projection();
        for threads in [4, 8] {
            assert_eq!(
                baseline,
                server(threads).render_sessions(&script, policy).simulated_projection(),
                "{} diverged at threads={threads}",
                policy.label()
            );
        }
    }
}

#[test]
fn joining_at_frame_k_matches_fresh_viewer_starting_at_k() {
    let server = server(1);
    let k = 3;
    let n = 3;
    let script = SessionScript::new()
        .join_at(0, SessionSpec::stream(ViewCondition::Average, k + n))
        .join_at(k, SessionSpec::stream(ViewCondition::Static, n).with_start(k));
    let rep = server.render_sessions(&script, SchedPolicy::RoundRobin);
    let joiner = &rep.sessions[1];
    assert_eq!(joiner.joined_round, k);
    assert_eq!(joiner.admitted_round, k);
    assert_eq!(joiner.frames, n);

    // Isolated fresh viewer: a private pipeline over the same trajectory's
    // frames [k, k + n) (same event-queue backend, no contention). Every
    // timing-independent stat must match the in-stream session exactly —
    // contention moves *when* requests complete, never what is fetched.
    let traj = server.trajectory(&ViewerSpec::perf(ViewCondition::Static, k + n));
    let mut cfg = server.config.clone();
    cfg.mem.mode = MemMode::EventQueue;
    let mut pipeline = server.shared.pipeline(cfg);
    let (mut visible, mut accesses, mut bytes, mut cycles, mut atg) =
        (0f64, 0f64, 0f64, 0f64, 0f64);
    let (mut hits, mut lookups) = (0u64, 0u64);
    for (cam, t) in &traj[k..] {
        let r = pipeline.render_frame(cam, *t, false);
        visible += r.n_visible as f64;
        accesses += r.traffic.total_dram_accesses() as f64;
        bytes += r.traffic.total_dram_bytes() as f64;
        cycles += r.sort.cycles as f64;
        atg += r.atg_ops as f64;
        hits += r.traffic.blend_sram.hits;
        lookups += r.traffic.blend_sram.lookups;
    }
    let nf = n as f64;
    assert_eq!(joiner.seq.avg_visible, visible / nf);
    assert_eq!(joiner.seq.avg_dram_accesses, accesses / nf);
    assert_eq!(joiner.seq.avg_dram_bytes, bytes / nf);
    assert_eq!(joiner.seq.avg_sort_cycles, cycles / nf);
    assert_eq!(joiner.seq.avg_atg_ops, atg / nf);
    assert_eq!(joiner.seq.sram_hit_rate, hits as f64 / lookups as f64);
}

#[test]
fn dwfq_and_edf_yield_distinct_deterministic_schedules() {
    let script = join_leave_script();
    let server = server(1);
    let rr = server.render_sessions(&script, SchedPolicy::RoundRobin);
    let dwfq = server.render_sessions(&script, SchedPolicy::Dwfq);
    let edf = server.render_sessions(&script, SchedPolicy::Edf);

    // Each policy is deterministic under replay…
    assert_eq!(
        dwfq.simulated_projection(),
        server.render_sessions(&script, SchedPolicy::Dwfq).simulated_projection()
    );
    assert_eq!(
        edf.simulated_projection(),
        server.render_sessions(&script, SchedPolicy::Edf).simulated_projection()
    );
    // …but the issue orders differ, so the contention profiles differ.
    assert_ne!(rr.simulated_projection(), dwfq.simulated_projection());
    assert_ne!(rr.simulated_projection(), edf.simulated_projection());

    // Ordering never changes what is transferred — only when.
    for (a, b) in rr.sessions.iter().zip(&dwfq.sessions) {
        assert_eq!(a.mem.total_bytes(), b.mem.total_bytes());
        assert_eq!(a.frames, b.frames);
    }
    for (a, b) in rr.sessions.iter().zip(&edf.sessions) {
        assert_eq!(a.mem.total_bytes(), b.mem.total_bytes());
    }
    // Deadline accounting is populated for deadline-bearing sessions.
    assert!(rr.sessions.iter().all(|s| s.target_fps > 0.0));
    assert!(rr.frame_latency_pctl.p99 >= rr.frame_latency_pctl.p50);
}

#[test]
fn warm_started_joiner_reuses_departed_intervals() {
    let server = server(1);
    let frames = 3;
    let base = SessionSpec::stream(ViewCondition::Static, frames);
    let cold_script = SessionScript::new()
        .join_at(0, base.clone())
        .leave_at(frames, 0)
        .join_at(frames, base.clone());
    let warm_script = SessionScript::new()
        .join_at(0, base.clone())
        .leave_at(frames, 0)
        .join_at(frames, base.clone().with_warm_from(0));

    let cold = server.render_sessions(&cold_script, SchedPolicy::RoundRobin);
    let warm = server.render_sessions(&warm_script, SchedPolicy::RoundRobin);
    let cold_j = &cold.sessions[1];
    let warm_j = &warm.sessions[1];
    assert!(!cold_j.warm_started);
    assert!(warm_j.warm_started, "retained intervals must be adopted");
    assert_eq!(warm_j.frames, frames);
    assert!(
        warm_j.aii_interval_hit_rate > cold_j.aii_interval_hit_rate,
        "warm {} vs cold {}: retained intervals must lift the hit rate",
        warm_j.aii_interval_hit_rate,
        cold_j.aii_interval_hit_rate
    );
    // Identical static views: the warm joiner never pays the phase-1 scan.
    assert_eq!(warm_j.aii_interval_hit_rate, 1.0);
}

#[test]
fn detached_sessions_resume_across_scheduler_runs_bit_identically() {
    // Run 1 streams frames [0, k) of the Static walk and ends; its
    // detached pipeline state is taken off the scheduler and seeded into a
    // second run whose join resumes it at start_frame = k. The resumed
    // session must continue the stream exactly — identical
    // timing-independent stats to the tail of an uninterrupted [0, k + n)
    // walk — at any host thread count.
    let k = 2;
    let n = 2;
    let chain = |threads: usize| {
        let server = server(threads);
        let first = SessionScript::new()
            .join_at(0, SessionSpec::stream(ViewCondition::Static, k));
        let mut sched = server.sessions(SchedPolicy::RoundRobin);
        let rep1 = sched.run(&first);
        assert_eq!(rep1.sessions[0].frames, k);
        let states = sched.take_detached();
        assert_eq!(states.len(), 1, "stream-end sessions detach too");
        assert_eq!(states[0].0, 0);
        assert_eq!(states[0].1.frame_idx(), k);

        // A fresh companion rides along so the second run has more than
        // one session — at threads > 1 that engages the two-phase round
        // engine, exercising the trace-port resume path.
        let second = SessionScript::new()
            .join_at(
                0,
                SessionSpec::stream(ViewCondition::Static, n)
                    .with_start(k)
                    .with_resume_from(0),
            )
            .join_at(0, SessionSpec::stream(ViewCondition::Average, n));
        let mut sched2 = server.sessions(SchedPolicy::RoundRobin);
        sched2.seed_detached(states);
        sched2.run(&second)
    };

    let rep2 = chain(1);
    let resumed = &rep2.sessions[0];
    assert!(resumed.resumed, "seeded state must be adopted");
    assert_eq!(resumed.frames, n);

    // Reference: a private pipeline streaming the uninterrupted
    // [0, k + n) walk; the resumed run must match its tail exactly
    // (contention moves *when* requests complete, never what is fetched).
    let server = server(1);
    let traj = server.trajectory(&ViewerSpec::perf(ViewCondition::Static, k + n));
    let mut cfg = server.config.clone();
    cfg.mem.mode = MemMode::EventQueue;
    let mut pipeline = server.shared.pipeline(cfg);
    let (mut visible, mut accesses, mut bytes, mut cycles, mut atg) =
        (0f64, 0f64, 0f64, 0f64, 0f64);
    for (i, (cam, t)) in traj.iter().enumerate() {
        let r = pipeline.render_frame(cam, *t, false);
        if i >= k {
            visible += r.n_visible as f64;
            accesses += r.traffic.total_dram_accesses() as f64;
            bytes += r.traffic.total_dram_bytes() as f64;
            cycles += r.sort.cycles as f64;
            atg += r.atg_ops as f64;
        }
    }
    let nf = n as f64;
    assert_eq!(resumed.seq.avg_visible, visible / nf);
    assert_eq!(resumed.seq.avg_dram_accesses, accesses / nf);
    assert_eq!(resumed.seq.avg_dram_bytes, bytes / nf);
    assert_eq!(resumed.seq.avg_sort_cycles, cycles / nf);
    assert_eq!(
        resumed.seq.avg_atg_ops,
        atg / nf,
        "ATG posteriori must survive the cross-run handoff"
    );

    // The whole resumed run is byte-identical across host thread counts
    // (the two-phase round engine path).
    let baseline = rep2.simulated_projection();
    for threads in [4, 8] {
        assert_eq!(
            baseline,
            chain(threads).simulated_projection(),
            "resumed run diverged at threads={threads}"
        );
    }

    // Without seeding, resume_from falls back to a cold start: the joiner
    // pays the frame-0 grouping/scan cost the resumed session skips.
    let cold_script = SessionScript::new().join_at(
        0,
        SessionSpec::stream(ViewCondition::Static, n)
            .with_start(k)
            .with_resume_from(0),
    );
    let cold = server.render_sessions(&cold_script, SchedPolicy::RoundRobin);
    assert!(!cold.sessions[0].resumed);
    assert!(
        cold.sessions[0].seq.avg_atg_ops > resumed.seq.avg_atg_ops,
        "cold {} vs resumed {}: the resumed session must reuse posteriori grouping",
        cold.sessions[0].seq.avg_atg_ops,
        resumed.seq.avg_atg_ops
    );
}

#[test]
fn tiny_dram_budget_defers_joins_but_stays_work_conserving() {
    let server = server(1);
    let script = SessionScript::new()
        .join_at(0, SessionSpec::stream(ViewCondition::Average, 2))
        .join_at(0, SessionSpec::stream(ViewCondition::Static, 2));
    // Budget sized for one fallback-estimate stream, not two.
    let fallback_demand = server.shared.prep.layout.total_span_bytes() as f64 / 10.0
        * gaucim::coordinator::session::DEFAULT_STREAM_FPS;
    let rep = server
        .sessions(SchedPolicy::RoundRobin)
        .dram_budget_gbps(fallback_demand * 1.5 / 1e9)
        .run(&script);

    let a = &rep.sessions[0];
    let b = &rep.sessions[1];
    assert_eq!(a.admitted_round, 0);
    assert_eq!(a.deferred_rounds, 0);
    assert!(b.admitted_round > 0, "budget must defer the second join");
    assert!(b.deferred_rounds > 0);
    // Work-conserving: both sessions still stream every frame.
    assert_eq!(a.frames, 2);
    assert_eq!(b.frames, 2);
    assert_eq!(rep.total_frames, 4);
    assert!(rep.rounds >= 3, "deferred admission stretches the stream");

    // Without a budget the same script admits everyone at round 0.
    let free = server.render_sessions(&script, SchedPolicy::RoundRobin);
    assert_eq!(free.sessions[1].admitted_round, 0);
    assert_eq!(free.rounds, 2);
}

#[test]
fn indexed_bookkeeping_matches_reference_sort_byte_for_byte() {
    // The scale-harness acceptance gate: the indexed hot path (event
    // index + linked ring + keyed heaps) must reproduce the historical
    // per-round-scan + full-sort bookkeeping byte-for-byte — the full
    // SessionBatchReport JSON, across host thread counts, for every
    // policy, over a join/leave stream.
    let script = join_leave_script();
    for policy in SchedPolicy::ALL {
        let reference = {
            let server = server(1);
            server.sessions(policy).with_reference_order().run(&script).simulated_projection()
        };
        for threads in [1, 4, 8] {
            let server = server(threads);
            assert_eq!(
                reference,
                server.sessions(policy).run(&script).simulated_projection(),
                "indexed {} diverged from the full-sort reference at threads={threads}",
                policy.label()
            );
        }
    }
}

#[test]
fn discarding_detached_state_never_changes_reports() {
    // `discard_detached` frees departed sessions' working sets (the
    // 10k-session memory contract) but must not perturb a single reported
    // statistic, and must leave nothing for `take_detached`.
    let script = join_leave_script();
    let server = server(1);
    for policy in SchedPolicy::ALL {
        let keep = server.sessions(policy).run(&script).simulated_projection();
        let mut sched = server.sessions(policy).discard_detached();
        let dropped = sched.run(&script).simulated_projection();
        assert_eq!(keep, dropped, "{} report changed under discard_detached", policy.label());
        assert!(sched.take_detached().is_empty(), "discard mode must park no state");
    }

    // Donors a later join warm-starts from are still retained in discard
    // mode — the warm handoff must keep working.
    let frames = 3;
    let base = SessionSpec::stream(ViewCondition::Static, frames);
    let warm_script = SessionScript::new()
        .join_at(0, base.clone())
        .leave_at(frames, 0)
        .join_at(frames, base.with_warm_from(0));
    let rep = server.sessions(SchedPolicy::RoundRobin).discard_detached().run(&warm_script);
    assert!(rep.sessions[1].warm_started, "warm_from donor must survive discard mode");
    assert_eq!(rep.sessions[1].aii_interval_hit_rate, 1.0);
}

#[test]
#[should_panic(expected = "leaves twice")]
fn duplicate_leave_in_a_5000_event_script_is_caught_in_one_pass() {
    // Regression for the former O(L²) duplicate-leave scan: validation of
    // a 5000-event script is a single pass over the leaves (a bitset),
    // so the duplicate at the very end is caught immediately — before a
    // single frame renders.
    let n = 2500;
    let mut script = SessionScript::new();
    for i in 0..n {
        script = script
            .join_at(i, SessionSpec::stream(ViewCondition::Static, 1))
            .leave_at(i + 2, i);
    }
    script = script.leave_at(n + 2, 0);
    server(1).render_sessions(&script, SchedPolicy::RoundRobin);
}

#[test]
fn leave_while_deferred_cancels_admission() {
    // A session still in the admission queue when its leave fires must be
    // dropped from the queue — never admitted, no ports, no demand leak.
    let server = server(1);
    let script = SessionScript::new()
        .join_at(0, SessionSpec::stream(ViewCondition::Average, 3))
        .join_at(0, SessionSpec::stream(ViewCondition::Static, 3))
        .leave_at(1, 1);
    let fallback_demand = server.shared.prep.layout.total_span_bytes() as f64 / 10.0
        * gaucim::coordinator::session::DEFAULT_STREAM_FPS;
    let rep = server
        .sessions(SchedPolicy::RoundRobin)
        .dram_budget_gbps(fallback_demand * 1.5 / 1e9)
        .run(&script);

    let b = &rep.sessions[1];
    assert_eq!(b.frames, 0, "a session deferred past its leave never streams");
    assert_eq!(b.left_round, 1);
    assert_eq!(
        rep.contended.viewers.len(),
        1,
        "the never-admitted session must not register ports"
    );
    assert_eq!(rep.sessions[0].frames, 3);
    assert_eq!(rep.total_frames, 3);
}
