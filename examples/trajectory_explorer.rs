//! Trajectory explorer: quantifies how the posteriori-knowledge techniques
//! (ATG phase 2, AII-Sort phase 2) respond to viewing conditions — the
//! user-behavior analysis of paper §2.2 turned into an experiment.
//!
//! For static / average / extreme head movement it reports per-frame ATG
//! regroup work, deformation flags, sort cycles, and SRAM hit rate, showing
//! the frame-to-frame-correlation exploitation decay as motion grows.
//!
//! Run: `cargo run --release --example trajectory_explorer`

use gaucim::camera::ViewCondition;
use gaucim::coordinator::App;
use gaucim::pipeline::FramePipeline;
use gaucim::scene::synth::SceneKind;
use gaucim::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env(&[]);
    let n = args.get_usize("gaussians", 30_000);
    let frames = args.get_usize("frames", 12);

    let mut app = App::new(SceneKind::DynamicLarge, n, 42);
    app.config = app.config.clone().with_resolution(640, 360);
    println!(
        "trajectory explorer: {} gaussians, {frames} frames per condition\n",
        app.scene.len()
    );
    println!(
        "{:<10} {:>12} {:>10} {:>12} {:>10} {:>9}",
        "condition", "atg ops/frm", "flags/frm", "sort cyc/frm", "sram hit", "minmax"
    );

    for cond in [
        ViewCondition::Static,
        ViewCondition::Average,
        ViewCondition::Extreme,
    ] {
        let seq = app.trajectory(cond, frames);
        let mut pipeline = FramePipeline::new(&app.scene, app.config.clone());
        let mut atg_ops = 0u64;
        let mut flags = 0u64;
        let mut sort_cycles = 0u64;
        let mut minmax = 0u64;
        let mut hits = 0u64;
        let mut lookups = 0u64;
        // Skip frame 0 (phase 1) in the averages: steady-state is the story.
        let mut steady_frames = 0u64;
        for (i, (cam, t)) in seq.iter().enumerate() {
            let r = pipeline.render_frame(cam, *t, false);
            if i == 0 {
                continue;
            }
            steady_frames += 1;
            atg_ops += r.atg_ops;
            flags += r.atg_flags;
            sort_cycles += r.sort.cycles;
            minmax += r.sort.minmax_scanned;
            hits += r.traffic.blend_sram.hits;
            lookups += r.traffic.blend_sram.lookups;
        }
        let d = steady_frames.max(1);
        println!(
            "{:<10} {:>12} {:>10} {:>12} {:>9.1}% {:>9}",
            cond.label(),
            atg_ops / d,
            flags / d,
            sort_cycles / d,
            100.0 * hits as f64 / lookups.max(1) as f64,
            minmax / d
        );
    }

    println!(
        "\nReading: ATG work and deformation flags grow with head-movement \
         speed;\nAII-Sort's min/max scans stay at 0 after frame 0 under all \
         conditions\n(stale-boundary routing degrades balance, never \
         correctness)."
    );
    Ok(())
}
