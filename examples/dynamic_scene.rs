//! End-to-end driver on a dynamic scene — the repository's E2E validation
//! run (EXPERIMENTS.md §E2E).
//!
//! Renders a head-movement trajectory over a Neural-3D-Video-class dynamic
//! scene through the full system: DR-FC culling of the 4D grid, ATG with
//! posteriori reuse, AII-Sort, DD3D-Flow blending — and, for the first
//! frame, cross-checks the AOT artifacts by rendering one tile through the
//! PJRT runtime (L1 Pallas kernel) and comparing against the native path.
//!
//! Run: `cargo run --release --example dynamic_scene [-- --frames 24]`

use gaucim::camera::ViewCondition;
use gaucim::coordinator::App;
use gaucim::pipeline::FramePipeline;
use gaucim::render::ppm;
use gaucim::scene::synth::SceneKind;
use gaucim::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env(&[]);
    let frames = args.get_usize("frames", 24);
    let n = args.get_usize("gaussians", 200_000);

    let mut app = App::new(SceneKind::DynamicLarge, n, 42);
    app.config = app.config.clone().with_resolution(640, 360);
    println!(
        "dynamic scene: {} gaussians, {} frames, average head-movement condition",
        app.scene.len(),
        frames
    );

    // --- PJRT cross-check on frame 0 (proves L1/L2/L3 compose) -----------
    #[cfg(feature = "xla")]
    {
        use gaucim::runtime::{Artifacts, BlendExecutor, HloExecutor, PreprocessExecutor};
        match Artifacts::discover() {
            Ok(artifacts) if artifacts.available() => {
                let client = HloExecutor::cpu_client()?;
                let pre = PreprocessExecutor::load(&client, &artifacts.preprocess_hlo())?;
                let blend = BlendExecutor::load(&client, &artifacts.blend_hlo())?;
                let cam = app.camera_template();
                let n_chunk = 1024.min(app.scene.len());
                let splats = pre.project_chunk(&app.scene.gaussians[..n_chunk], 0, &cam, 0.5)?;
                let mut sorted = splats.clone();
                sorted.sort_by(|a, b| a.depth.partial_cmp(&b.depth).unwrap());
                let x0 = cam.intrinsics.cx - 8.0;
                let y0 = cam.intrinsics.cy - 8.0;
                let pjrt_tile = blend.blend_tile(&sorted, x0, y0)?;
                let native_tile =
                    gaucim::runtime::blend_exec::cumulative_blend_reference(&sorted, x0, y0);
                let max_err = pjrt_tile
                    .iter()
                    .zip(&native_tile)
                    .flat_map(|(a, b)| (0..3).map(move |c| (a[c] - b[c]).abs()))
                    .fold(0.0f32, f32::max);
                println!(
                    "PJRT cross-check: {} splats through the AOT kernels, max |Δ| = {max_err:.5}",
                    sorted.len()
                );
                anyhow::ensure!(max_err < 2e-2, "PJRT/native divergence {max_err}");
            }
            _ => println!(
                "(artifacts not built — `make artifacts` to enable the PJRT cross-check)"
            ),
        }
    }
    #[cfg(not(feature = "xla"))]
    println!("(built without the `xla` feature — PJRT cross-check skipped)");

    // --- full trajectory through the pipeline ----------------------------
    let seq = app.trajectory(ViewCondition::Average, frames);
    let mut pipeline = FramePipeline::new(&app.scene, app.config.clone());
    let mut first_img = None;
    for (i, (cam, t)) in seq.iter().enumerate() {
        let render = i == 0 || i + 1 == frames;
        let r = pipeline.render_frame(cam, *t, render);
        if i == 0 {
            first_img = r.image.clone();
        }
        println!(
            "frame {i:>3}: t={t:.3} visible={:>6} dram={:>6.2} MB sramHit={:>5.1}% \
             atgOps={:>7} sortCyc={:>8} fps={:>7.1}",
            r.n_visible,
            r.traffic.total_dram_bytes() as f64 / 1e6,
            r.traffic.blend_sram.hit_rate() * 100.0,
            r.atg_ops,
            r.sort.cycles,
            1e9 / r.latency.pipelined_ns()
        );
    }
    if let Some(img) = first_img {
        ppm::save(&img, std::path::Path::new("dynamic_frame0.ppm"))?;
        println!("wrote dynamic_frame0.ppm");
    }

    let rep = app.run_sequence(ViewCondition::Average, frames.min(8), 4);
    println!("\nsummary: {}", rep.report.row());
    println!("PSNR vs reference (sampled frames): {:.2} dB", rep.psnr_db);
    Ok(())
}
