//! End-to-end driver on a dynamic scene — the repository's E2E validation
//! run (EXPERIMENTS.md §E2E).
//!
//! Serves a head-movement trajectory over a Neural-3D-Video-class dynamic
//! scene through the full system: DR-FC culling of the 4D grid, ATG with
//! posteriori reuse, AII-Sort, DD3D-Flow blending — with the per-frame
//! gaussian update stream enabled, so XOR-delta writes contend with render
//! reads on the shared memory system. For the first frame it cross-checks
//! the AOT artifacts by rendering one tile through the PJRT runtime (L1
//! Pallas kernel) and comparing against the native path.
//!
//! The trajectory runs as a **served session**: one `SessionSpec` stream
//! through the `SessionScript`/`RoundEngine` machinery the multi-viewer
//! server uses, not a stand-alone render loop — so the E2E run exercises
//! admission, deadline accounting, and the contended event-queue DRAM
//! model exactly as production serving does.
//!
//! Run: `cargo run --release --example dynamic_scene [-- --frames 24]`

use gaucim::camera::ViewCondition;
use gaucim::coordinator::{App, RenderServer, SchedPolicy, SessionScript, SessionSpec};
use gaucim::render::ppm;
use gaucim::scene::synth::SceneKind;
use gaucim::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env(&[]);
    let frames = args.get_usize("frames", 24);
    let n = args.get_usize("gaussians", 200_000);

    let mut app = App::new(SceneKind::DynamicLarge, n, 42);
    app.config = app.config.clone().with_resolution(640, 360);
    // Dynamic serving: stream per-frame gaussian update deltas into DRAM
    // (MemStage::Update) with dirty-cell cull reuse + AII retention on top.
    app.config.dynamic_updates = true;
    println!(
        "dynamic scene: {} gaussians, {} frames, average head-movement condition",
        app.scene.len(),
        frames
    );

    // --- PJRT cross-check on frame 0 (proves L1/L2/L3 compose) -----------
    #[cfg(feature = "xla")]
    {
        use gaucim::runtime::{Artifacts, BlendExecutor, HloExecutor, PreprocessExecutor};
        match Artifacts::discover() {
            Ok(artifacts) if artifacts.available() => {
                let client = HloExecutor::cpu_client()?;
                let pre = PreprocessExecutor::load(&client, &artifacts.preprocess_hlo())?;
                let blend = BlendExecutor::load(&client, &artifacts.blend_hlo())?;
                let cam = app.camera_template();
                let n_chunk = 1024.min(app.scene.len());
                let splats = pre.project_chunk(&app.scene.gaussians[..n_chunk], 0, &cam, 0.5)?;
                let mut sorted = splats.clone();
                sorted.sort_by(|a, b| a.depth.partial_cmp(&b.depth).unwrap());
                let x0 = cam.intrinsics.cx - 8.0;
                let y0 = cam.intrinsics.cy - 8.0;
                let pjrt_tile = blend.blend_tile(&sorted, x0, y0)?;
                let native_tile =
                    gaucim::runtime::blend_exec::cumulative_blend_reference(&sorted, x0, y0);
                let max_err = pjrt_tile
                    .iter()
                    .zip(&native_tile)
                    .flat_map(|(a, b)| (0..3).map(move |c| (a[c] - b[c]).abs()))
                    .fold(0.0f32, f32::max);
                println!(
                    "PJRT cross-check: {} splats through the AOT kernels, max |Δ| = {max_err:.5}",
                    sorted.len()
                );
                anyhow::ensure!(max_err < 2e-2, "PJRT/native divergence {max_err}");
            }
            _ => println!(
                "(artifacts not built — `make artifacts` to enable the PJRT cross-check)"
            ),
        }
    }
    #[cfg(not(feature = "xla"))]
    println!("(built without the `xla` feature — PJRT cross-check skipped)");

    // --- the trajectory as a served session ------------------------------
    let server = RenderServer::new(app.scene.clone(), app.config.clone());
    let script = SessionScript::new().join_at(
        0,
        SessionSpec::stream(ViewCondition::Average, frames).with_deadline_fps(60.0),
    );
    let batch = server.render_sessions(&script, SchedPolicy::RoundRobin);
    let s = &batch.sessions[0];
    println!(
        "session: {} frames in {} rounds, miss-rate {:.3}, \
         simulated latency p50/p99 {:.1}/{:.1} µs",
        s.frames,
        batch.rounds,
        s.deadline_miss_rate,
        s.frame_latency_pctl.p50 / 1e3,
        s.frame_latency_pctl.p99 / 1e3
    );
    if let Some(d) = &s.seq.dynamic {
        println!(
            "update stream: {} records over {} dirty / {} clean cells, \
             {:.1} KB delta vs {:.1} KB raw, cull-reuse hit {:.3}",
            d.update.updated_records,
            d.update.dirty_cells,
            d.update.clean_cells,
            d.update.delta_bytes as f64 / 1e3,
            d.update.raw_bytes as f64 / 1e3,
            d.cull_reuse.cell_hit_rate()
        );
    }

    // Frame-0 image through the single-frame App path (same pipeline).
    let (img, _) = app.render_one(app.scene.time_span.0);
    ppm::save(&img, std::path::Path::new("dynamic_frame0.ppm"))?;
    println!("wrote dynamic_frame0.ppm");

    let rep = app.run_sequence(ViewCondition::Average, frames.min(8), 4);
    println!("\nsummary: {}", rep.report.row());
    println!("PSNR vs reference (sampled frames): {:.2} dB", rep.psnr_db);
    Ok(())
}
