//! Quickstart: synthesize a small static scene, render one frame through
//! the full 3DGauCIM pipeline (DR-FC + ATG + AII-Sort + DD3D-Flow blending),
//! score it against the exact reference renderer, and print the Table-I
//! style report.
//!
//! Run: `cargo run --release --example quickstart`

use gaucim::coordinator::App;
use gaucim::render::ppm;
use gaucim::scene::synth::SceneKind;

fn main() -> anyhow::Result<()> {
    // 20 k Gaussians is laptop-friendly; pass the paper scale via the CLI
    // (`gaucim render --gaussians 1000000`) when you have the minutes.
    let mut app = App::new(SceneKind::StaticLarge, 20_000, 42);
    app.config = app.config.clone().with_resolution(640, 360);

    println!("scene: {} ({} gaussians)", app.scene.name, app.scene.len());

    let (img, rep) = app.render_one(0.0);
    ppm::save(&img, std::path::Path::new("quickstart.ppm"))?;

    println!("wrote quickstart.ppm ({}x{})", img.width, img.height);
    println!("{}", rep.report.row());
    println!("PSNR vs exact reference: {:.2} dB", rep.psnr_db);
    println!(
        "visible splats: {}   DRAM: {:.2} MB   SRAM hit rate: {:.1}%",
        rep.avg_visible,
        rep.avg_dram_bytes / 1e6,
        rep.sram_hit_rate * 100.0
    );
    println!(
        "modeled latency: preprocess {:.3} ms | sort {:.3} ms | blend {:.3} ms",
        rep.latency.preprocess_ns / 1e6,
        rep.latency.sort_ns / 1e6,
        rep.latency.blend_ns / 1e6
    );
    Ok(())
}
