//! Edge-deployment power/FPS report: the Table-I style comparison of
//! 3DGauCIM against the GSCore-class accelerator model and the Jetson AGX
//! Orin roofline, on both scene classes.
//!
//! Run: `cargo run --release --example edge_power_report [-- --gaussians 50000]`

use gaucim::baseline::{gscore, jetson, GscoreModel, JetsonModel};
use gaucim::camera::ViewCondition;
use gaucim::coordinator::App;
use gaucim::culling::{GridConfig, GridPartition};
use gaucim::energy::StageLatency;
use gaucim::scene::synth::SceneKind;
use gaucim::scene::DramLayout;
use gaucim::util::cli::Args;
use gaucim::util::json::Json;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env(&[]);
    let n = args.get_usize("gaussians", 30_000);
    let frames = args.get_usize("frames", 8);

    println!("=== Edge power report (workload: {n} gaussians, {frames} frames) ===\n");
    let mut rows = Vec::new();

    for kind in [SceneKind::StaticLarge, SceneKind::DynamicLarge] {
        let mut app = App::new(kind, n, 42);
        app.config = app.config.clone().with_resolution(640, 360);
        let cond = if kind == SceneKind::DynamicLarge {
            ViewCondition::Average
        } else {
            ViewCondition::Static
        };

        let rep = app.run_sequence(cond, frames, frames.max(1));
        println!("{}", rep.report.row());
        println!(
            "    PSNR {:.2} dB | SRAM hit {:.1}% | {:.1} visible splats/frame",
            rep.psnr_db,
            rep.sram_hit_rate * 100.0,
            rep.avg_visible
        );

        // GSCore structural model on the identical scene + trajectory.
        let grid_cfg = if app.scene.dynamic {
            GridConfig::new(4)
        } else {
            GridConfig::static_scene(4)
        };
        let grid = GridPartition::build(&app.scene, grid_cfg);
        let layout = DramLayout::build(&app.scene, &grid);
        let model = GscoreModel::new(&app.scene, &layout, 640, 360);
        let mut g_lat = StageLatency::default();
        let mut g_energy = 0.0;
        let traj = app.trajectory(cond, frames.min(4));
        for (cam, t) in &traj {
            let f = model.render_frame(cam, *t);
            g_lat.add(&f.latency);
            g_energy += f.energy.total_pj();
        }
        let g_lat = g_lat.scale(1.0 / traj.len() as f64);
        let g_fps = 1e9 / g_lat.pipelined_ns();
        let g_power = (g_energy / traj.len() as f64) * 1e-12 * g_fps + 0.12;
        println!(
            "  gscore-class model            {:>7.1} FPS {:>7.3} W  (published: {} FPS / {} W / {} mm² @28nm)",
            g_fps,
            g_power,
            gscore::published::FPS_STATIC_LARGE,
            gscore::published::POWER_W,
            gscore::published::AREA_MM2
        );

        // Jetson Orin roofline on the same per-frame work.
        let jf = JetsonModel::from_workload(
            (rep.energy.dcim_pj / 0.033) as u64,
            rep.avg_dram_bytes as u64,
        );
        println!(
            "  jetson-orin roofline          {:>7.1} FPS {:>7.3} W  (published: {} FPS @ {} W)\n",
            jf.fps,
            jetson::published::POWER_W,
            jetson::published::FPS_DYNAMIC,
            jetson::published::POWER_W
        );

        rows.push(
            Json::obj()
                .set("scene", app.scene.name.as_str())
                .set("gaucim_fps", rep.report.fps)
                .set("gaucim_power_w", rep.report.power_w)
                .set("gaucim_area_mm2", rep.report.area_mm2)
                .set("gaucim_psnr_db", rep.psnr_db)
                .set("gscore_fps", g_fps)
                .set("jetson_fps", jf.fps),
        );
    }

    std::fs::create_dir_all("reports")?;
    std::fs::write("reports/edge_power_report.json", Json::Arr(rows).pretty())?;
    println!("wrote reports/edge_power_report.json");
    Ok(())
}
