//! Multi-viewer serving demo: N concurrent viewer sessions over one shared
//! scene preparation, batched through the [`RenderServer`].
//!
//! Measures host simulation throughput (viewers × frames / wall-clock) for
//! the sequential baseline vs the parallel batch, probes the intra-frame
//! parallel executor (`pipeline::par`) on a single-viewer trajectory
//! (per-stage host wall-clock at `threads = 1` vs the configured count),
//! then runs the same specs through the **shared, contended event-queue
//! memory system** twice — single-threaded lockstep and the two-phase
//! parallel scheme — asserting the contended roll-ups are bit-identical
//! before reporting the parallel one. Everything lands in
//! `BENCH_server.json` (the `contended_mem` block, per-stage host
//! wall-clock percentiles, and `speedup_vs_serial`) so future PRs have a
//! perf trajectory to beat.
//!
//! Run: `cargo run --release --example multi_viewer [-- --viewers 4 --frames 8 --threads 0]`
//! (`--threads 0` = auto: `PALLAS_THREADS` env, else available parallelism)

use gaucim::bench::write_bench_json;
use gaucim::camera::ViewCondition;
use gaucim::coordinator::{Percentiles, RenderServer, ViewerSpec};
use gaucim::pipeline::{resolve_threads, HostStageWall, PipelineConfig};
use gaucim::scene::synth::{SceneKind, SynthParams};
use gaucim::util::cli::Args;
use gaucim::util::json::Json;
use std::time::Instant;

/// Run one single-viewer trajectory at a fixed thread count and return the
/// pipeline's host per-stage wall-clock accounting.
fn executor_probe(
    server: &RenderServer,
    spec: &ViewerSpec,
    threads: usize,
) -> (HostStageWall, f64) {
    let cfg = PipelineConfig { threads, ..server.config.clone() };
    let mut pipeline = server.shared.pipeline(cfg);
    let traj = server.trajectory(spec);
    let t0 = Instant::now();
    for (cam, t) in &traj {
        std::hint::black_box(pipeline.render_frame(cam, *t, false));
    }
    let wall = t0.elapsed().as_secs_f64();
    (pipeline.host_wall().clone(), wall)
}

fn stage_wall_json(wall: &HostStageWall) -> Json {
    let sort_pctl = Percentiles::of(&wall.sort_samples);
    let blend_pctl = Percentiles::of(&wall.blend_samples);
    Json::obj()
        .set("frames", wall.frames)
        .set("sort_s_total", wall.sort_s)
        .set("blend_s_total", wall.blend_s)
        .set("frame_s_total", wall.frame_s)
        .set("sort_s_p50", sort_pctl.p50)
        .set("sort_s_p99", sort_pctl.p99)
        .set("blend_s_p50", blend_pctl.p50)
        .set("blend_s_p99", blend_pctl.p99)
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env(&[]);
    let n = args.get_usize("gaussians", 20_000);
    let n_viewers = args.get_usize("viewers", 4);
    let frames = args.get_usize("frames", 8);
    let width = args.get_usize("width", 640);
    let height = args.get_usize("height", 360);
    let threads = resolve_threads(args.get_usize("threads", 0));

    let scene = SynthParams::new(SceneKind::DynamicLarge, n).with_seed(42).generate();
    let config =
        PipelineConfig::paper(true).with_resolution(width, height).with_threads(threads);
    let mut server = RenderServer::new(scene, config);
    println!(
        "multi-viewer server: {} gaussians, {n_viewers} viewers × {frames} frames @ \
         {width}x{height}, {threads} executor threads",
        server.shared.scene.len()
    );

    // Mixed viewing conditions, like a real audience.
    let conditions =
        [ViewCondition::Average, ViewCondition::Static, ViewCondition::Extreme];
    let specs: Vec<ViewerSpec> = (0..n_viewers)
        .map(|i| ViewerSpec::perf(conditions[i % conditions.len()], frames))
        .collect();

    // Warm-up (page in the shared preparation, stabilize timing).
    server.render_viewer(0, &specs[0]);

    // ---- serial baselines (threads = 1) --------------------------------
    server.set_threads(1);
    let t0 = Instant::now();
    let sequential: Vec<_> = specs
        .iter()
        .enumerate()
        .map(|(i, s)| server.render_viewer(i, s))
        .collect();
    let seq_wall_s = t0.elapsed().as_secs_f64();
    let contended_serial = server.render_batch_contended(&specs);

    // ---- parallel runs --------------------------------------------------
    server.set_threads(threads);
    let batch = server.render_batch(&specs);
    let contended = server.render_batch_contended(&specs);

    // Two-phase determinism: the parallel contended batch must reproduce
    // the single-threaded lockstep bit-for-bit (wall-clock aside).
    assert_eq!(
        contended_serial.simulated_projection(),
        contended.simulated_projection(),
        "two-phase contended batch diverged from the lockstep reference"
    );

    println!("\nper-viewer reports (modeled accelerator FPS/W):");
    for rep in &batch.viewers {
        println!("  {}", rep.report.row());
    }
    for (seq_rep, par_rep) in sequential.iter().zip(&batch.viewers) {
        assert_eq!(
            seq_rep.avg_dram_accesses, par_rep.avg_dram_accesses,
            "parallel viewer stats must match sequential runs"
        );
    }

    let total_frames = batch.total_frames;
    let seq_fps = total_frames as f64 / seq_wall_s.max(1e-12);
    let speedup = seq_wall_s / batch.wall_s.max(1e-12);
    println!("\nhost throughput (frames across all viewers per second):");
    println!("  sequential: {total_frames} frames in {seq_wall_s:.3} s  → {seq_fps:.1} frames/s");
    println!(
        "  batched:    {total_frames} frames in {:.3} s  → {:.1} frames/s  ({speedup:.2}x)",
        batch.wall_s, batch.aggregate_frames_per_s
    );

    // ---- intra-frame executor probe (sort + blend host wall-clock) -----
    let (wall_serial, frame_wall_serial) = executor_probe(&server, &specs[0], 1);
    let (wall_par, frame_wall_par) = executor_probe(&server, &specs[0], threads);
    let sort_speedup = wall_serial.sort_s / wall_par.sort_s.max(1e-12);
    let blend_speedup = wall_serial.blend_s / wall_par.blend_s.max(1e-12);
    let frame_speedup = frame_wall_serial / frame_wall_par.max(1e-12);
    let contended_speedup = contended_serial.wall_s / contended.wall_s.max(1e-12);
    println!("\nintra-frame executor ({threads} threads vs serial, single viewer):");
    println!(
        "  sort  {:.3} ms → {:.3} ms  ({sort_speedup:.2}x)",
        wall_serial.sort_s * 1e3,
        wall_par.sort_s * 1e3
    );
    println!(
        "  blend {:.3} ms → {:.3} ms  ({blend_speedup:.2}x)",
        wall_serial.blend_s * 1e3,
        wall_par.blend_s * 1e3
    );
    println!(
        "  contended batch {:.3} s → {:.3} s  ({contended_speedup:.2}x)",
        contended_serial.wall_s, contended.wall_s
    );

    let mem = contended
        .contended_mem
        .as_ref()
        .expect("contended batch must produce a memory roll-up");
    for (seq_rep, con_rep) in sequential.iter().zip(&contended.viewers) {
        assert_eq!(
            seq_rep.avg_dram_accesses, con_rep.avg_dram_accesses,
            "contention must never change what is transferred, only when"
        );
    }
    println!("\ncontended memory system ({} channels, {} shards):", mem.channels, mem.shards);
    println!(
        "  makespan {:.1} µs, fairness {:.3}, channel util p50/p90/p99 = {:.2}/{:.2}/{:.2}",
        mem.makespan_ns / 1e3,
        mem.fairness,
        mem.channel_util_pctl.p50,
        mem.channel_util_pctl.p90,
        mem.channel_util_pctl.p99
    );
    println!(
        "  simulated preprocess latency p50/p90/p99 = {:.1}/{:.1}/{:.1} µs",
        mem.preprocess_latency_pctl.p50 / 1e3,
        mem.preprocess_latency_pctl.p90 / 1e3,
        mem.preprocess_latency_pctl.p99 / 1e3
    );
    println!(
        "  simulated blend latency p50/p90/p99 = {:.1}/{:.1}/{:.1} µs",
        mem.blend_latency_pctl.p50 / 1e3,
        mem.blend_latency_pctl.p90 / 1e3,
        mem.blend_latency_pctl.p99 / 1e3
    );
    for v in &mem.viewers {
        println!(
            "  viewer-{}: busy {:.1} µs (wait {:.1} µs, {} stalls)",
            v.viewer,
            v.total_busy_ns() / 1e3,
            v.total_wait_ns() / 1e3,
            v.preprocess.stalls + v.blend.stalls
        );
    }

    let record = Json::obj()
        .set("gaussians", server.shared.scene.len())
        .set("viewers", n_viewers)
        .set("frames_per_viewer", frames)
        .set("width", width)
        .set("height", height)
        .set("threads", threads)
        .set("sequential_wall_s", seq_wall_s)
        .set("batch_wall_s", batch.wall_s)
        .set("sequential_frames_per_s", seq_fps)
        .set("aggregate_frames_per_s", batch.aggregate_frames_per_s)
        .set("speedup", speedup)
        .set(
            "host_parallelism",
            std::thread::available_parallelism().map(usize::from).unwrap_or(1),
        )
        .set("stage_wall_serial", stage_wall_json(&wall_serial))
        .set("stage_wall_parallel", stage_wall_json(&wall_par))
        .set(
            "speedup_vs_serial",
            Json::obj()
                .set("sort", sort_speedup)
                .set("blend", blend_speedup)
                .set("frame", frame_speedup)
                .set("contended", contended_speedup),
        )
        .set("contended_wall_serial_s", contended_serial.wall_s)
        .set("contended_wall_parallel_s", contended.wall_s)
        .set("contended_mem", mem.to_json());
    write_bench_json("BENCH_server.json", &record)?;
    println!("\nwrote BENCH_server.json (contended_mem + stage_wall + speedup_vs_serial)");
    Ok(())
}
