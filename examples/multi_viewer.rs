//! Multi-viewer serving demo: N concurrent viewer sessions over one shared
//! scene preparation, batched through the [`RenderServer`].
//!
//! Measures host simulation throughput (viewers × frames / wall-clock) for
//! the sequential baseline vs the parallel batch, then runs the same specs
//! through the **shared, contended event-queue memory system**
//! (`render_batch_contended`) and reports per-stage simulated latency and
//! channel-utilization percentiles. Everything lands in
//! `BENCH_server.json` (including the `contended_mem` block) so future PRs
//! have a perf trajectory to beat.
//!
//! Run: `cargo run --release --example multi_viewer [-- --viewers 4 --frames 8]`

use gaucim::bench::write_bench_json;
use gaucim::camera::ViewCondition;
use gaucim::coordinator::{RenderServer, ViewerSpec};
use gaucim::pipeline::PipelineConfig;
use gaucim::scene::synth::{SceneKind, SynthParams};
use gaucim::util::cli::Args;
use gaucim::util::json::Json;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env(&[]);
    let n = args.get_usize("gaussians", 20_000);
    let n_viewers = args.get_usize("viewers", 4);
    let frames = args.get_usize("frames", 8);
    let width = args.get_usize("width", 640);
    let height = args.get_usize("height", 360);

    let scene = SynthParams::new(SceneKind::DynamicLarge, n).with_seed(42).generate();
    let config = PipelineConfig::paper(true).with_resolution(width, height);
    let server = RenderServer::new(scene, config);
    println!(
        "multi-viewer server: {} gaussians, {n_viewers} viewers × {frames} frames @ {width}x{height}",
        server.shared.scene.len()
    );

    // Mixed viewing conditions, like a real audience.
    let conditions =
        [ViewCondition::Average, ViewCondition::Static, ViewCondition::Extreme];
    let specs: Vec<ViewerSpec> = (0..n_viewers)
        .map(|i| ViewerSpec::perf(conditions[i % conditions.len()], frames))
        .collect();

    // Warm-up (page in the shared preparation, stabilize timing).
    server.render_viewer(0, &specs[0]);

    // Sequential baseline: the same sessions one after another.
    let t0 = Instant::now();
    let sequential: Vec<_> = specs
        .iter()
        .enumerate()
        .map(|(i, s)| server.render_viewer(i, s))
        .collect();
    let seq_wall_s = t0.elapsed().as_secs_f64();

    // Parallel batch.
    let batch = server.render_batch(&specs);

    println!("\nper-viewer reports (modeled accelerator FPS/W):");
    for rep in &batch.viewers {
        println!("  {}", rep.report.row());
    }
    for (seq_rep, par_rep) in sequential.iter().zip(&batch.viewers) {
        assert_eq!(
            seq_rep.avg_dram_accesses, par_rep.avg_dram_accesses,
            "parallel viewer stats must match sequential runs"
        );
    }

    let total_frames = batch.total_frames;
    let seq_fps = total_frames as f64 / seq_wall_s.max(1e-12);
    let speedup = seq_wall_s / batch.wall_s.max(1e-12);
    println!("\nhost throughput (frames across all viewers per second):");
    println!("  sequential: {total_frames} frames in {seq_wall_s:.3} s  → {seq_fps:.1} frames/s");
    println!(
        "  batched:    {total_frames} frames in {:.3} s  → {:.1} frames/s  ({speedup:.2}x)",
        batch.wall_s, batch.aggregate_frames_per_s
    );

    // Contended memory mode: the same specs on one shared event-queue
    // MemorySystem, stepped in deterministic lockstep rounds.
    let contended = server.render_batch_contended(&specs);
    let mem = contended
        .contended_mem
        .as_ref()
        .expect("contended batch must produce a memory roll-up");
    for (seq_rep, con_rep) in sequential.iter().zip(&contended.viewers) {
        assert_eq!(
            seq_rep.avg_dram_accesses, con_rep.avg_dram_accesses,
            "contention must never change what is transferred, only when"
        );
    }
    println!("\ncontended memory system ({} channels, {} shards):", mem.channels, mem.shards);
    println!(
        "  makespan {:.1} µs, fairness {:.3}, channel util p50/p90/p99 = {:.2}/{:.2}/{:.2}",
        mem.makespan_ns / 1e3,
        mem.fairness,
        mem.channel_util_pctl.p50,
        mem.channel_util_pctl.p90,
        mem.channel_util_pctl.p99
    );
    println!(
        "  simulated preprocess latency p50/p90/p99 = {:.1}/{:.1}/{:.1} µs",
        mem.preprocess_latency_pctl.p50 / 1e3,
        mem.preprocess_latency_pctl.p90 / 1e3,
        mem.preprocess_latency_pctl.p99 / 1e3
    );
    println!(
        "  simulated blend latency p50/p90/p99 = {:.1}/{:.1}/{:.1} µs",
        mem.blend_latency_pctl.p50 / 1e3,
        mem.blend_latency_pctl.p90 / 1e3,
        mem.blend_latency_pctl.p99 / 1e3
    );
    for v in &mem.viewers {
        println!(
            "  viewer-{}: busy {:.1} µs (wait {:.1} µs, {} stalls)",
            v.viewer,
            v.total_busy_ns() / 1e3,
            v.total_wait_ns() / 1e3,
            v.preprocess.stalls + v.blend.stalls
        );
    }

    let record = Json::obj()
        .set("gaussians", server.shared.scene.len())
        .set("viewers", n_viewers)
        .set("frames_per_viewer", frames)
        .set("width", width)
        .set("height", height)
        .set("sequential_wall_s", seq_wall_s)
        .set("batch_wall_s", batch.wall_s)
        .set("sequential_frames_per_s", seq_fps)
        .set("aggregate_frames_per_s", batch.aggregate_frames_per_s)
        .set("speedup", speedup)
        .set(
            "host_parallelism",
            std::thread::available_parallelism().map(usize::from).unwrap_or(1),
        )
        .set("contended_mem", mem.to_json());
    write_bench_json("BENCH_server.json", &record)?;
    println!("\nwrote BENCH_server.json (with contended_mem block)");
    Ok(())
}
