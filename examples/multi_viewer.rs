//! Multi-viewer serving demo: N concurrent viewer sessions over one shared
//! scene preparation, batched through the [`RenderServer`].
//!
//! Measures host simulation throughput (viewers × frames / wall-clock) for
//! the sequential baseline vs the parallel batch, probes the intra-frame
//! parallel executor (`pipeline::par`) on a single-viewer trajectory
//! (per-stage host wall-clock at `threads = 1` vs the configured count),
//! times the scalar-vs-lane-batched blend datapath on numeric frames
//! (`speedup_vs_serial.render_backend` + per-backend `stage_wall_render_*`
//! blocks), then runs the same specs through the **shared, contended event-queue
//! memory system** twice — single-threaded lockstep and the two-phase
//! parallel scheme — asserting the contended roll-ups are bit-identical
//! before reporting the parallel one. Everything lands in
//! `BENCH_server.json` (the `contended_mem` block, per-stage host
//! wall-clock percentiles, and `speedup_vs_serial`) so future PRs have a
//! perf trajectory to beat.
//!
//! The **session layer** rides along: a join/leave [`SessionScript`] —
//! the built-in demo, or a declarative JSON file via
//! `--session-script <path>` — runs under every [`SchedPolicy`]
//! (round-robin / DWFQ / EDF) after asserting that round-robin over a
//! static script reproduces the contended batch's roll-up bit-for-bit,
//! and that the host-parallel round-engine run is byte-identical to the
//! serial schedule; the per-policy deadline-miss rates, frame-latency
//! percentiles, fairness, and the serial-vs-parallel session speedup
//! (`speedup_vs_serial.sessions`) land in `BENCH_server.json` (the
//! `sessions` block is diffed across thread counts by the CI
//! `session-smoke` job). Pass `--sessions` to run the session layer only.
//!
//! Pass `--loadgen steady|flash|diurnal` to run the **scale harness**
//! instead: a seeded synthetic workload from [`gaucim::coordinator::loadgen`]
//! (default 10k sessions, `--loadgen-sessions N --loadgen-seed S`) streams
//! through the session scheduler at a session-count ladder, once under the
//! indexed hot path and once under the historical full-sort reference
//! bookkeeping, asserting the two reports byte-identical at every rung
//! and for every policy. Simulated roll-ups (loadgen parameters, per-rung
//! report digests, full per-policy reports at the smallest rung) land in
//! the `scale` block (diffed across `PALLAS_THREADS` by the CI
//! `scale-smoke` job); scheduler-overhead ns/round ladders, rounds/s, and
//! the indexed-vs-reference speedup land in `scale_host`.
//!
//! Pass `--residency-mb MB` to run the **residency sweep** instead: DRAM
//! becomes a shard-granular cache of that capacity over the compressed
//! backing store ([`gaucim::memory::residency`]), and the contended batch
//! runs once per prefetch policy (none / next-frame-cull / lookahead:2).
//! Per-policy hit rate, evictions, stall time, and compression ratio land
//! in the `residency` block (simulated-only, diffed across
//! `PALLAS_THREADS` by the CI `residency-smoke` job); host fps deltas
//! versus the fully-resident run land in `residency_host`.
//!
//! Every mode also assembles a schema-versioned `metrics` block through
//! [`gaucim::obs::Registry`] — `metrics.deterministic` holds the
//! simulated-only roll-ups CI diffs across `PALLAS_THREADS`
//! (`obs-smoke`), `metrics.host` the wall-clock-derived numbers. Pass
//! `--trace-out trace.json` to additionally record every contended batch
//! / session stream as a **simulated-time** Chrome trace (stage spans,
//! per-channel DRAM spans, session lifecycle instants) loadable in
//! Perfetto — see `rust/src/obs/README.md`.
//!
//! Run: `cargo run --release --example multi_viewer [-- --viewers 4 --frames 8 --threads 0]`
//! (`--threads 0` = auto: `PALLAS_THREADS` env, else available parallelism)

use gaucim::bench::write_bench_json;
use gaucim::camera::ViewCondition;
use gaucim::coordinator::session::DEFAULT_STREAM_FPS;
use gaucim::coordinator::{
    ContendedMemReport, DynamicSequenceStats, LoadGen, LoadPreset, RenderServer, SchedImpl,
    SchedPolicy, SequenceReport, SessionBatchReport, SessionScript, SessionSpec, ViewerSpec,
};
use gaucim::memory::PrefetchPolicy;
use gaucim::obs::{sink, Component, LatencyLadder, Registry, TraceSink};
use gaucim::pipeline::{resolve_threads, HostStageWall, PipelineConfig};
use gaucim::render::RenderBackend;
use gaucim::scene::synth::{SceneKind, SynthParams};
use gaucim::util::cli::Args;
use gaucim::util::json::Json;
use std::time::Instant;

/// Dump the recorded simulated-time trace as Chrome trace-event JSON
/// (`--trace-out <path>`; load in Perfetto / `chrome://tracing`). A no-op
/// when tracing was not requested.
fn write_trace(path: Option<&str>, trace: Option<&TraceSink>) -> anyhow::Result<()> {
    if let (Some(path), Some(trace)) = (path, trace) {
        let doc = trace.lock().expect("tracer lock poisoned").chrome_json().pretty();
        std::fs::write(path, doc)
            .map_err(|e| anyhow::anyhow!("--trace-out {path}: {e}"))?;
        println!("wrote {path} (Chrome trace-event JSON, simulated timeline)");
    }
    Ok(())
}

/// Run one single-viewer trajectory at a fixed thread count and return the
/// pipeline's host per-stage wall-clock accounting.
fn executor_probe(
    server: &RenderServer,
    spec: &ViewerSpec,
    threads: usize,
) -> (HostStageWall, f64) {
    let cfg = PipelineConfig { threads, ..server.config.clone() };
    let mut pipeline = server.shared.pipeline(cfg);
    let traj = server.trajectory(spec);
    let t0 = Instant::now();
    for (cam, t) in &traj {
        std::hint::black_box(pipeline.render_frame(cam, *t, false));
    }
    let wall = t0.elapsed().as_secs_f64();
    (pipeline.host_wall().clone(), wall)
}

/// Run one single-viewer trajectory with **numeric** rendering (the blend
/// stage actually shades pixels) on the given blend datapath, and return
/// the host per-stage wall-clock. Outputs are bit-identical across
/// backends, so only the timing differs — this is the scalar-vs-lanes
/// perf record.
fn backend_probe(
    server: &RenderServer,
    spec: &ViewerSpec,
    threads: usize,
    backend: RenderBackend,
) -> HostStageWall {
    let cfg = PipelineConfig { threads, render_backend: backend, ..server.config.clone() };
    let mut pipeline = server.shared.pipeline(cfg);
    let traj = server.trajectory(spec);
    for (cam, t) in &traj {
        std::hint::black_box(pipeline.render_frame(cam, *t, true));
    }
    pipeline.host_wall().clone()
}

/// The built-in demo stream (used when no `--session-script` file is
/// given): two viewers join at frame 0 with different deadlines/weights, a
/// third joins mid-stream (trajectory cursor at its join round), one
/// leaves mid-stream, and a fourth warm-starts its AII intervals from the
/// leaver's retained state.
fn demo_session_script(frames: usize) -> SessionScript {
    let join_round = (frames / 2).max(1);
    let leave_round = frames.max(2);
    SessionScript::new()
        .join_at(
            0,
            SessionSpec::stream(ViewCondition::Average, frames + join_round)
                .with_deadline_fps(120.0),
        )
        .join_at(
            0,
            SessionSpec::stream(ViewCondition::Static, frames + join_round)
                .with_deadline_fps(60.0)
                .with_weight(2.0),
        )
        .join_at(
            join_round,
            SessionSpec::stream(ViewCondition::Extreme, frames)
                .with_start(join_round)
                .with_deadline_fps(90.0),
        )
        .leave_at(leave_round, 1)
        .join_at(
            leave_round,
            SessionSpec::stream(ViewCondition::Static, frames)
                .with_deadline_fps(90.0)
                .with_warm_from(1),
        )
}

/// Run the session-scheduler layer: assert the round-robin static-script
/// bit-compatibility with `render_batch_contended`, then stream `script`
/// under every policy and report the per-policy deadline/fairness
/// roll-ups (simulated quantities only — the block is diffed across host
/// thread counts by CI). When a serial round-robin reference is handed
/// in, the parallel round-robin run is asserted byte-identical to it (the
/// round-engine gate). Returns the `sessions` JSON block plus the
/// round-robin run's host wall-clock (the session-speedup denominator).
fn session_bench(
    server: &RenderServer,
    specs: &[ViewerSpec],
    script: &SessionScript,
    batch_mem: Option<&ContendedMemReport>,
    serial_rr: Option<&SessionBatchReport>,
) -> (Json, f64) {
    // 1 — acceptance gate: round-robin sessions over a no-join/no-leave
    // script must reproduce the contended batch bit-for-bit. The full run
    // hands in the roll-up it already computed; `--sessions`-only mode
    // renders the batch here.
    let static_script = SessionScript::from_specs(specs);
    let rr_static = server.render_sessions(&static_script, SchedPolicy::RoundRobin);
    let batch_json = match batch_mem {
        Some(mem) => mem.to_json().pretty(),
        None => server
            .render_batch_contended(specs)
            .contended_mem
            .as_ref()
            .expect("contended batch must produce a memory roll-up")
            .to_json()
            .pretty(),
    };
    assert_eq!(
        batch_json,
        rr_static.contended.to_json().pretty(),
        "round-robin session scheduler diverged from render_batch_contended"
    );

    // 2 — the live stream under every policy.
    println!("\nsession scheduler (join/leave stream, {} sessions):", script.n_sessions());
    let mut policies = Json::obj();
    let mut rr_wall_s = 0.0;
    for policy in SchedPolicy::ALL {
        let rep = server.render_sessions(script, policy);
        if policy == SchedPolicy::RoundRobin {
            rr_wall_s = rep.wall_s;
            if let Some(serial) = serial_rr {
                assert_eq!(
                    serial.simulated_projection(),
                    rep.simulated_projection(),
                    "host-parallel session rounds diverged from the serial schedule"
                );
            }
        }
        println!(
            "  {:<12} rounds {:>3}  miss-rate {:.3}  fairness {:.3}  latency p50/p99 {:.1}/{:.1} µs  ({:.3} s host)",
            policy.label(),
            rep.rounds,
            rep.deadline_miss_rate,
            rep.fairness(),
            rep.frame_latency_pctl.p50 / 1e3,
            rep.frame_latency_pctl.p99 / 1e3,
            rep.wall_s
        );
        policies = policies.set(policy.label(), rep.to_json());
    }
    (
        Json::obj()
            .set("static_round_robin_matches_contended", true)
            .set("policies", policies),
        rr_wall_s,
    )
}

/// One scale-harness scheduler run: the script under `policy` with the
/// given bookkeeping implementation, detached-state collection off (the
/// 10k-session memory contract), and the optional admission budget.
/// Returns the report plus the per-round scheduler-overhead samples.
fn scale_run(
    server: &RenderServer,
    script: &SessionScript,
    policy: SchedPolicy,
    budget_gbps: Option<f64>,
    imp: SchedImpl,
) -> (SessionBatchReport, Vec<f64>) {
    let mut sched = server.sessions(policy).with_sched_impl(imp).discard_detached();
    if let Some(gbps) = budget_gbps {
        sched = sched.dram_budget_gbps(gbps);
    }
    let rep = sched.run(script);
    let overhead = sched.last_overhead_ns().to_vec();
    (rep, overhead)
}

/// FNV-1a 64-bit digest of a report's simulated projection — a compact
/// deterministic fingerprint for the large-N rungs whose full JSON would
/// bloat the BENCH record.
fn fnv1a64(s: &str) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in s.as_bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

fn stage_wall_json(wall: &HostStageWall) -> Json {
    let sort_pctl = wall.sort_ladder();
    let blend_pctl = wall.blend_ladder();
    Json::obj()
        .set("frames", wall.frames())
        .set("sort_s_total", wall.sort_s())
        .set("blend_s_total", wall.blend_s())
        .set("frame_s_total", wall.frame_s())
        .set("sort_s_p50", sort_pctl.p50)
        .set("sort_s_p99", sort_pctl.p99)
        .set("blend_s_p50", blend_pctl.p50)
        .set("blend_s_p99", blend_pctl.p99)
        .set("sort_s_pctl", sort_pctl.to_json())
        .set("blend_s_pctl", blend_pctl.to_json())
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env(&[]);
    let n = args.get_usize("gaussians", 20_000);
    let n_viewers = args.get_usize("viewers", 4);
    let frames = args.get_usize("frames", 8);
    let width = args.get_usize("width", 640);
    let height = args.get_usize("height", 360);
    let threads = resolve_threads(args.get_usize("threads", 0));

    let scene = SynthParams::new(SceneKind::DynamicLarge, n).with_seed(42).generate();
    let mut config =
        PipelineConfig::paper(true).with_resolution(width, height).with_threads(threads);
    // Blend datapath override (default: PALLAS_RENDER_BACKEND env, else
    // lanes). The scalar-vs-lanes probe below forces both explicitly.
    if let Some(s) = args.get("render-backend") {
        config.render_backend = RenderBackend::from_label(s)
            .ok_or_else(|| anyhow::anyhow!("--render-backend must be scalar|lanes, got '{s}'"))?;
    }
    let mut server = RenderServer::new(scene, config);
    // Opt-in simulated-time frame tracing: every contended batch / session
    // stream below records stage + DRAM-channel spans into one sink, dumped
    // as Chrome trace-event JSON on exit. Timestamps are simulated ns, so
    // the file is byte-identical across PALLAS_THREADS (CI `obs-smoke`).
    let trace_out = args.get("trace-out").map(str::to_string);
    let trace_sink = trace_out.as_ref().map(|_| sink());
    if let Some(trace) = &trace_sink {
        server.set_tracer(trace.clone());
    }
    println!(
        "multi-viewer server: {} gaussians, {n_viewers} viewers × {frames} frames @ \
         {width}x{height}, {threads} executor threads",
        server.shared.scene.len()
    );

    // Mixed viewing conditions, like a real audience.
    let conditions =
        [ViewCondition::Average, ViewCondition::Static, ViewCondition::Extreme];
    let specs: Vec<ViewerSpec> = (0..n_viewers)
        .map(|i| ViewerSpec::perf(conditions[i % conditions.len()], frames))
        .collect();

    // ---- residency sweep (`--residency-mb MB`, CI `residency-smoke`) ---
    // Treat DRAM as a shard-granular cache of the given capacity over the
    // compressed backing store and sweep the prefetch policies. Each
    // policy runs the contended batch under the lockstep (threads = 1)
    // and two-phase parallel schedulers and asserts the simulated
    // projections bit-identical; the `residency` block holds simulated
    // quantities only (hit rate, evictions, stall time, compression
    // ratio) so CI can diff it across PALLAS_THREADS, while host fps
    // deltas land in the separate `residency_host` block.
    let residency_mb = args.get_parsed("residency-mb", 0.0f64);
    if residency_mb > 0.0 {
        let baseline = server.render_batch_contended(&specs);
        let base_fps = baseline.total_frames as f64 / baseline.wall_s.max(1e-12);
        println!("\nresidency sweep ({residency_mb} MB DRAM over compressed backing store):");
        let mut blocks = Json::obj();
        let mut host = Json::obj();
        let mut hit_rates: Vec<(String, f64)> = Vec::new();
        for policy in [
            PrefetchPolicy::None,
            PrefetchPolicy::NextFrameCull,
            PrefetchPolicy::TrajectoryLookahead { k: 2 },
        ] {
            let mut cfg = server.config.clone();
            cfg.mem.residency.capacity_mb = residency_mb;
            cfg.mem.residency.policy = policy;
            let mut paged = RenderServer::new(server.shared.scene.clone(), cfg);
            paged.set_threads(1);
            let serial = paged.render_batch_contended(&specs);
            paged.set_threads(threads);
            let par = paged.render_batch_contended(&specs);
            assert_eq!(
                serial.simulated_projection(),
                par.simulated_projection(),
                "paged contended batch diverged between lockstep and two-phase ({})",
                policy.label()
            );
            let mem = par.contended_mem.as_ref().expect("contended roll-up");
            let res = mem
                .residency
                .as_ref()
                .expect("sub-capacity residency run must produce a residency roll-up");
            let fps = par.total_frames as f64 / par.wall_s.max(1e-12);
            println!(
                "  {:<16} hit-rate {:.3}  evictions {:>6}  stall {:>9.1} µs  \
                 ratio {:.2}x  {:+.1} frames/s vs resident",
                policy.label(),
                res.stats.hit_rate(),
                res.stats.evictions,
                res.stats.stall_ns / 1e3,
                res.compression_ratio,
                fps - base_fps
            );
            hit_rates.push((policy.label(), res.stats.hit_rate()));
            blocks = blocks.set(&policy.label(), res.to_json());
            host = host.set(
                &policy.label(),
                Json::obj()
                    .set("frames_per_s", fps)
                    .set("fps_delta_vs_resident", fps - base_fps),
            );
        }
        let rate = |label: &str| {
            hit_rates.iter().find(|(l, _)| l == label).map(|&(_, r)| r).unwrap_or(0.0)
        };
        assert!(
            rate("lookahead:2") > rate("none"),
            "trajectory lookahead must beat no-prefetch on the standard trajectory \
             (hit rates: {hit_rates:?})"
        );
        let mut metrics = Registry::new();
        metrics.deterministic =
            Component::new().set("residency", blocks.clone());
        metrics.host = Component::new().set("residency_host", host.clone());
        let record = Json::obj()
            .set("gaussians", server.shared.scene.len())
            .set("viewers", n_viewers)
            .set("frames_per_viewer", frames)
            .set("width", width)
            .set("height", height)
            .set("threads", threads)
            .set("residency_mb", residency_mb)
            .set("residency", blocks)
            .set("residency_host", host)
            .set("metrics", metrics.to_json());
        write_bench_json("BENCH_server.json", &record)?;
        println!("\nwrote BENCH_server.json (residency block only)");
        write_trace(trace_out.as_deref(), trace_sink.as_ref())?;
        return Ok(());
    }

    // ---- dynamic serving sweep (`--dynamic`, CI `dynamic-smoke`) -------
    // Stream per-frame gaussian update deltas through the MemStage::Update
    // DRAM port while the same specs render, and measure the temporal-
    // coherence savings built on top: XOR-delta vs raw update bytes,
    // dirty-cell cull-reuse hit rate, and AII posteriori retention vs
    // cold-start sort cycles. The `dynamic` block holds simulated
    // quantities only, so CI can diff it across PALLAS_THREADS.
    if args.flag("dynamic") {
        // Static reference: the identical specs with the update stream off.
        server.set_threads(1);
        let static_serial = server.render_batch_contended(&specs);
        server.set_threads(threads);
        let static_par = server.render_batch_contended(&specs);
        assert_eq!(
            static_serial.simulated_projection(),
            static_par.simulated_projection(),
            "static contended batch diverged between lockstep and two-phase"
        );

        // Dynamic serving: update writes contend with render reads, clean
        // cells replay last frame's cull verdict, AII posteriori intervals
        // stay live across scene updates.
        let mut cfg = server.config.clone();
        cfg.dynamic_updates = true;
        let mut warm = RenderServer::new(server.shared.scene.clone(), cfg.clone());
        warm.set_threads(1);
        let warm_serial = warm.render_batch_contended(&specs);
        warm.set_threads(threads);
        let warm_par = warm.render_batch_contended(&specs);
        assert_eq!(
            warm_serial.simulated_projection(),
            warm_par.simulated_projection(),
            "dynamic contended batch diverged between lockstep and two-phase"
        );

        // AII cold-start reference: the identical update stream, but the
        // sorter's posteriori intervals drop on every scene update —
        // isolating what frame-to-frame retention saves.
        let mut cold_cfg = cfg.clone();
        cold_cfg.aii_retain = false;
        let mut cold = RenderServer::new(server.shared.scene.clone(), cold_cfg);
        cold.set_threads(threads);
        let cold_par = cold.render_batch_contended(&specs);

        let fold = |reps: &[SequenceReport]| {
            let mut d = DynamicSequenceStats::default();
            for r in reps.iter().filter_map(|r| r.dynamic.as_ref()) {
                d.update.add(&r.update);
                d.cull_reuse.add(&r.cull_reuse);
                d.update_dram_bytes += r.update_dram_bytes;
            }
            d
        };
        let mean_frame_bytes = |reps: &[SequenceReport]| {
            reps.iter().map(|r| r.avg_dram_bytes).sum::<f64>() / reps.len().max(1) as f64
        };
        let mean_sort_cycles = |reps: &[SequenceReport]| {
            reps.iter().map(|r| r.avg_sort_cycles).sum::<f64>() / reps.len().max(1) as f64
        };
        let totals = fold(&warm_par.viewers);
        let warm_sort = mean_sort_cycles(&warm_par.viewers);
        let cold_sort = mean_sort_cycles(&cold_par.viewers);
        let mem = warm_par
            .contended_mem
            .as_ref()
            .expect("contended batch must produce a memory roll-up");
        let update_busy_ns: f64 =
            mem.viewers.iter().filter_map(|v| v.update).map(|u| u.busy_ns).sum();

        assert!(
            totals.update.delta_bytes < totals.update.raw_bytes,
            "temporal XOR-delta must ship fewer bytes than raw record refresh \
             ({} vs {})",
            totals.update.delta_bytes,
            totals.update.raw_bytes
        );
        assert!(
            warm_sort < cold_sort,
            "AII posteriori retention must beat cold-start sort cycles \
             ({warm_sort:.1} vs {cold_sort:.1})"
        );

        println!("\ndynamic serving (update stream + temporal coherence):");
        println!(
            "  traffic: static {:.1} KB/frame → dynamic {:.1} KB/frame \
             (update stream busy {:.1} µs)",
            mean_frame_bytes(&static_par.viewers) / 1e3,
            mean_frame_bytes(&warm_par.viewers) / 1e3,
            update_busy_ns / 1e3
        );
        println!(
            "  updates: {} records over {} dirty / {} clean cells, \
             {:.1} KB delta vs {:.1} KB raw ({:.2}x)",
            totals.update.updated_records,
            totals.update.dirty_cells,
            totals.update.clean_cells,
            totals.update.delta_bytes as f64 / 1e3,
            totals.update.raw_bytes as f64 / 1e3,
            totals.update.raw_bytes as f64 / totals.update.delta_bytes.max(1) as f64
        );
        println!(
            "  cull reuse: {:.3} cell hit rate ({} reused / {} fetched, {:.1} KB saved)",
            totals.cull_reuse.cell_hit_rate(),
            totals.cull_reuse.cells_reused,
            totals.cull_reuse.cells_fetched,
            totals.cull_reuse.bytes_saved as f64 / 1e3
        );
        println!(
            "  AII: warm {warm_sort:.1} sort cycles/frame vs cold {cold_sort:.1} \
             ({:.2}x)",
            cold_sort / warm_sort.max(1e-12)
        );

        // Assembled through the registry: every value is a simulated
        // quantity, so the whole block lives in the deterministic section.
        let dynamic_block = Component::new()
            .set("static_mean_frame_bytes", mean_frame_bytes(&static_par.viewers))
            .set("dynamic_mean_frame_bytes", mean_frame_bytes(&warm_par.viewers))
            .set("update_raw_bytes", totals.update.raw_bytes)
            .set("update_delta_bytes", totals.update.delta_bytes)
            .set("update_dram_bytes", totals.update_dram_bytes)
            .set("update_busy_ns", update_busy_ns)
            .set("updated_records", totals.update.updated_records)
            .set("dirty_cells", totals.update.dirty_cells)
            .set("clean_cells", totals.update.clean_cells)
            .set("cull_cells_reused", totals.cull_reuse.cells_reused)
            .set("cull_cells_fetched", totals.cull_reuse.cells_fetched)
            .set("cull_bytes_saved", totals.cull_reuse.bytes_saved)
            .set("cull_cell_hit_rate", totals.cull_reuse.cell_hit_rate())
            .set("aii_warm_sort_cycles", warm_sort)
            .set("aii_cold_sort_cycles", cold_sort);
        let mut metrics = Registry::new();
        metrics.deterministic = Component::new().set("dynamic", dynamic_block.clone());
        let record = Json::obj()
            .set("gaussians", server.shared.scene.len())
            .set("viewers", n_viewers)
            .set("frames_per_viewer", frames)
            .set("width", width)
            .set("height", height)
            .set("threads", threads)
            .set("dynamic", dynamic_block.to_json())
            .set("metrics", metrics.to_json());
        write_bench_json("BENCH_server.json", &record)?;
        println!("\nwrote BENCH_server.json (dynamic block only)");
        write_trace(trace_out.as_deref(), trace_sink.as_ref())?;
        return Ok(());
    }

    // ---- scale harness (`--loadgen <preset>`, CI `scale-smoke`) --------
    // Synthetic session-scale workloads from `coordinator::loadgen`: run
    // the generated script under the indexed scheduler hot path and the
    // historical full-sort reference bookkeeping, assert the reports
    // byte-identical, and record the scheduler-overhead ladder at each
    // rung of the session-count ladder. The `scale` block holds simulated
    // quantities only so CI can diff it across PALLAS_THREADS; overhead
    // ns/round, rounds/s, and the indexed-vs-reference speedup land in
    // `scale_host`.
    if let Some(label) = args.get("loadgen") {
        let preset = LoadPreset::from_label(label).ok_or_else(|| {
            anyhow::anyhow!("--loadgen must be steady|flash|diurnal, got '{label}'")
        })?;
        let n_sessions = args.get_usize("loadgen-sessions", 10_000).max(1);
        let seed = args.get_u64("loadgen-seed", 42);
        // Admission budget sized from the preset's target concurrency:
        // the scheduler charges a cold stream span/10 bytes per frame at
        // the default stream FPS, so this budget keeps roughly
        // `target_concurrency` mean-demand streams admitted at once.
        let fallback_demand_bytes_per_s =
            server.shared.prep.layout.total_span_bytes() as f64 / 10.0 * DEFAULT_STREAM_FPS;
        let budget_for = |lg: &LoadGen| {
            lg.target_concurrency.map(|tc| tc as f64 * fallback_demand_bytes_per_s / 1e9)
        };
        // Session-count ladder up to the requested scale.
        let mut ladder: Vec<usize> =
            [100, 1_000, n_sessions].iter().map(|&k| k.min(n_sessions)).collect();
        ladder.dedup();
        println!(
            "\nscale harness: '{}' preset, {} sessions (seed {}), ladder {:?}",
            preset.label(),
            n_sessions,
            seed,
            ladder
        );

        let mut det_rungs = Json::obj();
        let mut host_rungs = Json::obj();
        for &n in &ladder {
            let lg = LoadGen::preset(preset, n, seed);
            let script = lg.generate();
            let budget = budget_for(&lg);
            let (rep_idx, oh_idx) =
                scale_run(&server, &script, SchedPolicy::Dwfq, budget, SchedImpl::Indexed);
            let (rep_ref, oh_ref) =
                scale_run(&server, &script, SchedPolicy::Dwfq, budget, SchedImpl::ReferenceSort);
            assert_eq!(
                rep_idx.simulated_projection(),
                rep_ref.simulated_projection(),
                "indexed scheduler diverged from the full-sort reference (N={n})"
            );
            let rounds = rep_idx.rounds.max(1) as f64;
            let sum_idx: f64 = oh_idx.iter().sum();
            let sum_ref: f64 = oh_ref.iter().sum();
            let speedup = sum_ref / sum_idx.max(1.0);
            println!(
                "  N={n:>6}  rounds {:>5}  peak-live {:>4}  sched overhead \
                 {:>9.1} → {:>8.1} ns/round  ({speedup:.2}x)  [{:.2} s host]",
                rep_idx.rounds,
                rep_idx.peak_live,
                sum_ref / rounds,
                sum_idx / rounds,
                rep_idx.wall_s
            );
            det_rungs = det_rungs.set(
                &format!("n{n}"),
                Json::obj()
                    .set("sessions", n)
                    .set("rounds", rep_idx.rounds)
                    .set("total_frames", rep_idx.total_frames)
                    .set("peak_live", rep_idx.peak_live)
                    .set("deadline_miss_rate", rep_idx.deadline_miss_rate)
                    .set("fairness", rep_idx.fairness())
                    .set("admission_wait_rounds_pctl", rep_idx.admission_wait_rounds.to_json())
                    .set(
                        "report_digest_fnv1a64",
                        format!("{:016x}", fnv1a64(&rep_idx.simulated_projection())),
                    ),
            );
            host_rungs = host_rungs.set(
                &format!("n{n}"),
                Json::obj()
                    .set("wall_s_indexed", rep_idx.wall_s)
                    .set("wall_s_reference", rep_ref.wall_s)
                    .set("rounds_per_s", rep_idx.rounds as f64 / rep_idx.wall_s.max(1e-12))
                    .set("sched_overhead_ns_per_round_indexed", sum_idx / rounds)
                    .set("sched_overhead_ns_per_round_reference", sum_ref / rounds)
                    .set("sched_overhead_indexed_pctl", LatencyLadder::of(&oh_idx).to_json())
                    .set(
                        "sched_overhead_reference_pctl",
                        LatencyLadder::of(&oh_ref).to_json(),
                    )
                    .set("speedup_vs_reference", speedup),
            );
        }

        // Every policy at the smallest rung: full reports (the CI diff
        // surface) plus the byte-identity gate per policy.
        let n0 = ladder[0];
        let lg0 = LoadGen::preset(preset, n0, seed);
        let script0 = lg0.generate();
        let budget0 = budget_for(&lg0);
        let mut policies = Json::obj();
        for policy in SchedPolicy::ALL {
            let (idx, _) = scale_run(&server, &script0, policy, budget0, SchedImpl::Indexed);
            let (refr, _) =
                scale_run(&server, &script0, policy, budget0, SchedImpl::ReferenceSort);
            assert_eq!(
                idx.simulated_projection(),
                refr.simulated_projection(),
                "indexed scheduler diverged from the full-sort reference ({} @ N={n0})",
                policy.label()
            );
            println!(
                "  {:<12} N={n0:>4}  miss-rate {:.3}  fairness {:.3}  \
                 admission wait p50/p99 {:.1}/{:.1} rounds",
                policy.label(),
                idx.deadline_miss_rate,
                idx.fairness(),
                idx.admission_wait_rounds.p50,
                idx.admission_wait_rounds.p99
            );
            policies = policies.set(policy.label(), idx.to_json());
        }

        let scale_det = Json::obj()
            .set("preset", preset.label())
            .set("loadgen", LoadGen::preset(preset, n_sessions, seed).component().to_json())
            .set("ladder", det_rungs)
            .set("policies_at_smallest", policies);
        let scale_host = Json::obj().set("ladder", host_rungs);
        let mut metrics = Registry::new();
        metrics.deterministic = Component::new().set("scale", scale_det.clone());
        metrics.host = Component::new().set("scale_host", scale_host.clone());
        let record = Json::obj()
            .set("gaussians", server.shared.scene.len())
            .set("width", width)
            .set("height", height)
            .set("threads", threads)
            .set("loadgen_preset", preset.label())
            .set("loadgen_sessions", n_sessions)
            .set("loadgen_seed", seed)
            .set("scale", scale_det)
            .set("scale_host", scale_host)
            .set("metrics", metrics.to_json());
        write_bench_json("BENCH_server.json", &record)?;
        println!("\nwrote BENCH_server.json (scale block only)");
        write_trace(trace_out.as_deref(), trace_sink.as_ref())?;
        return Ok(());
    }

    // The session stream: a declarative JSON script from disk
    // (`--session-script path`), or the built-in demo.
    let script = match args.get("session-script") {
        Some(path) => {
            let text = std::fs::read_to_string(path)
                .map_err(|e| anyhow::anyhow!("--session-script {path}: {e}"))?;
            let script = SessionScript::from_json_str(&text)
                .map_err(|e| anyhow::anyhow!("--session-script {path}: {e}"))?;
            println!(
                "session script: {path} ({} events, {} sessions)",
                script.events.len(),
                script.n_sessions()
            );
            script
        }
        None => demo_session_script(frames),
    };

    if args.flag("sessions") {
        // Session-layer-only mode (the CI `session-smoke` job): run the
        // scheduler stream and write just the `sessions` block (plus the
        // serial-vs-parallel session speedup).
        server.set_threads(1);
        let sessions_serial = server.render_sessions(&script, SchedPolicy::RoundRobin);
        server.set_threads(threads);
        let (sessions, rr_wall_s) =
            session_bench(&server, &specs, &script, None, Some(&sessions_serial));
        let sessions_speedup = sessions_serial.wall_s / rr_wall_s.max(1e-12);
        let mut metrics = Registry::new();
        metrics.deterministic = Component::new().set("sessions", sessions.clone());
        metrics.host = Component::new().set("speedup_sessions", sessions_speedup);
        let record = Json::obj()
            .set("gaussians", server.shared.scene.len())
            .set("viewers", n_viewers)
            .set("frames_per_viewer", frames)
            .set("width", width)
            .set("height", height)
            .set("threads", threads)
            .set("speedup_vs_serial", Json::obj().set("sessions", sessions_speedup))
            .set("sessions", sessions)
            .set("metrics", metrics.to_json());
        write_bench_json("BENCH_server.json", &record)?;
        println!("\nwrote BENCH_server.json (sessions block only)");
        write_trace(trace_out.as_deref(), trace_sink.as_ref())?;
        return Ok(());
    }

    // Warm-up (page in the shared preparation, stabilize timing).
    server.render_viewer(0, &specs[0]);

    // ---- serial baselines (threads = 1) --------------------------------
    server.set_threads(1);
    let t0 = Instant::now();
    let sequential: Vec<_> = specs
        .iter()
        .enumerate()
        .map(|(i, s)| server.render_viewer(i, s))
        .collect();
    let seq_wall_s = t0.elapsed().as_secs_f64();
    let contended_serial = server.render_batch_contended(&specs);

    // ---- parallel runs --------------------------------------------------
    server.set_threads(threads);
    let batch = server.render_batch(&specs);
    let contended = server.render_batch_contended(&specs);

    // Two-phase determinism: the parallel contended batch must reproduce
    // the single-threaded lockstep bit-for-bit (wall-clock aside).
    assert_eq!(
        contended_serial.simulated_projection(),
        contended.simulated_projection(),
        "two-phase contended batch diverged from the lockstep reference"
    );

    println!("\nper-viewer reports (modeled accelerator FPS/W):");
    for rep in &batch.viewers {
        println!("  {}", rep.report.row());
    }
    for (seq_rep, par_rep) in sequential.iter().zip(&batch.viewers) {
        assert_eq!(
            seq_rep.avg_dram_accesses, par_rep.avg_dram_accesses,
            "parallel viewer stats must match sequential runs"
        );
    }

    let total_frames = batch.total_frames;
    let seq_fps = total_frames as f64 / seq_wall_s.max(1e-12);
    let speedup = seq_wall_s / batch.wall_s.max(1e-12);
    println!("\nhost throughput (frames across all viewers per second):");
    println!("  sequential: {total_frames} frames in {seq_wall_s:.3} s  → {seq_fps:.1} frames/s");
    println!(
        "  batched:    {total_frames} frames in {:.3} s  → {:.1} frames/s  ({speedup:.2}x)",
        batch.wall_s, batch.aggregate_frames_per_s
    );

    // ---- intra-frame executor probe (sort + blend host wall-clock) -----
    let (wall_serial, frame_wall_serial) = executor_probe(&server, &specs[0], 1);
    let (wall_par, frame_wall_par) = executor_probe(&server, &specs[0], threads);
    let sort_speedup = wall_serial.sort_s() / wall_par.sort_s().max(1e-12);
    let blend_speedup = wall_serial.blend_s() / wall_par.blend_s().max(1e-12);
    let frame_speedup = frame_wall_serial / frame_wall_par.max(1e-12);
    let contended_speedup = contended_serial.wall_s / contended.wall_s.max(1e-12);
    println!("\nintra-frame executor ({threads} threads vs serial, single viewer):");
    println!(
        "  sort  {:.3} ms → {:.3} ms  ({sort_speedup:.2}x)",
        wall_serial.sort_s() * 1e3,
        wall_par.sort_s() * 1e3
    );
    println!(
        "  blend {:.3} ms → {:.3} ms  ({blend_speedup:.2}x)",
        wall_serial.blend_s() * 1e3,
        wall_par.blend_s() * 1e3
    );
    println!(
        "  contended batch {:.3} s → {:.3} s  ({contended_speedup:.2}x)",
        contended_serial.wall_s, contended.wall_s
    );

    // ---- render-backend probe (scalar vs lane-batched blend datapath) --
    // Numeric frames this time: the blend stage shades every pixel, so
    // `blend_s` is dominated by the rasterizer inner loop the lane kernel
    // vectorizes. Images and NMC stats are bit-identical across backends
    // (asserted by `tests/render_backend.rs` and the CI report diff);
    // only wall-clock may differ.
    let wall_rb_scalar = backend_probe(&server, &specs[0], threads, RenderBackend::Scalar);
    let wall_rb_lanes = backend_probe(&server, &specs[0], threads, RenderBackend::Lanes);
    let backend_speedup = wall_rb_scalar.blend_s() / wall_rb_lanes.blend_s().max(1e-12);
    println!("\nrender backend (numeric blend datapath, {threads} threads):");
    println!(
        "  blend scalar {:.3} ms → lanes {:.3} ms  ({backend_speedup:.2}x)",
        wall_rb_scalar.blend_s() * 1e3,
        wall_rb_lanes.blend_s() * 1e3
    );

    let mem = contended
        .contended_mem
        .as_ref()
        .expect("contended batch must produce a memory roll-up");
    for (seq_rep, con_rep) in sequential.iter().zip(&contended.viewers) {
        assert_eq!(
            seq_rep.avg_dram_accesses, con_rep.avg_dram_accesses,
            "contention must never change what is transferred, only when"
        );
    }
    println!("\ncontended memory system ({} channels, {} shards):", mem.channels, mem.shards);
    println!(
        "  makespan {:.1} µs, fairness {:.3}, channel util p50/p90/p99 = {:.2}/{:.2}/{:.2}",
        mem.makespan_ns / 1e3,
        mem.fairness,
        mem.channel_util_pctl.p50,
        mem.channel_util_pctl.p90,
        mem.channel_util_pctl.p99
    );
    println!(
        "  simulated preprocess latency p50/p90/p99 = {:.1}/{:.1}/{:.1} µs",
        mem.preprocess_latency_pctl.p50 / 1e3,
        mem.preprocess_latency_pctl.p90 / 1e3,
        mem.preprocess_latency_pctl.p99 / 1e3
    );
    println!(
        "  simulated blend latency p50/p90/p99 = {:.1}/{:.1}/{:.1} µs",
        mem.blend_latency_pctl.p50 / 1e3,
        mem.blend_latency_pctl.p90 / 1e3,
        mem.blend_latency_pctl.p99 / 1e3
    );
    for v in &mem.viewers {
        println!(
            "  viewer-{}: busy {:.1} µs (wait {:.1} µs, {} stalls)",
            v.viewer,
            v.total_busy_ns() / 1e3,
            v.total_wait_ns() / 1e3,
            v.preprocess.stalls + v.blend.stalls
        );
    }

    // Session layer (join/leave stream + per-policy roll-ups); the
    // bit-compat gate reuses the contended roll-up computed above, and the
    // serial round-robin reference gates the host-parallel round engine.
    server.set_threads(1);
    let sessions_serial = server.render_sessions(&script, SchedPolicy::RoundRobin);
    server.set_threads(threads);
    let (sessions, rr_wall_s) =
        session_bench(&server, &specs, &script, Some(mem), Some(&sessions_serial));
    let sessions_speedup = sessions_serial.wall_s / rr_wall_s.max(1e-12);
    println!(
        "  sessions (round-robin) {:.3} s → {:.3} s  ({sessions_speedup:.2}x)",
        sessions_serial.wall_s, rr_wall_s
    );

    let speedups = Json::obj()
        .set("sort", sort_speedup)
        .set("blend", blend_speedup)
        .set("frame", frame_speedup)
        .set("contended", contended_speedup)
        .set("render_backend", backend_speedup)
        .set("sessions", sessions_speedup);

    // The typed metrics registry: `deterministic` holds only simulated
    // quantities (byte-identical across PALLAS_THREADS — the CI `obs-smoke`
    // diff surface), `host` holds wall-clock-derived numbers and is
    // excluded from cross-thread diffs.
    let mut metrics = Registry::new();
    metrics.deterministic = Component::new()
        .set("contended_mem", mem.component())
        .set("sessions", sessions.clone());
    metrics.host = Component::new()
        .set("sequential_wall_s", seq_wall_s)
        .set("batch_wall_s", batch.wall_s)
        .set("sequential_frames_per_s", seq_fps)
        .set("aggregate_frames_per_s", batch.aggregate_frames_per_s)
        .set("speedup", speedup)
        .set("contended_wall_serial_s", contended_serial.wall_s)
        .set("contended_wall_parallel_s", contended.wall_s)
        .set("stage_wall_serial", stage_wall_json(&wall_serial))
        .set("stage_wall_parallel", stage_wall_json(&wall_par))
        .set("stage_wall_render_scalar", stage_wall_json(&wall_rb_scalar))
        .set("stage_wall_render_lanes", stage_wall_json(&wall_rb_lanes))
        .set("speedup_vs_serial", speedups.clone());

    let record = Json::obj()
        .set("gaussians", server.shared.scene.len())
        .set("viewers", n_viewers)
        .set("frames_per_viewer", frames)
        .set("width", width)
        .set("height", height)
        .set("threads", threads)
        .set("sequential_wall_s", seq_wall_s)
        .set("batch_wall_s", batch.wall_s)
        .set("sequential_frames_per_s", seq_fps)
        .set("aggregate_frames_per_s", batch.aggregate_frames_per_s)
        .set("speedup", speedup)
        .set(
            "host_parallelism",
            std::thread::available_parallelism().map(usize::from).unwrap_or(1),
        )
        .set("stage_wall_serial", stage_wall_json(&wall_serial))
        .set("stage_wall_parallel", stage_wall_json(&wall_par))
        .set("stage_wall_render_scalar", stage_wall_json(&wall_rb_scalar))
        .set("stage_wall_render_lanes", stage_wall_json(&wall_rb_lanes))
        .set("speedup_vs_serial", speedups)
        .set("contended_wall_serial_s", contended_serial.wall_s)
        .set("contended_wall_parallel_s", contended.wall_s)
        .set("contended_mem", mem.to_json())
        .set("sessions", sessions)
        .set("metrics", metrics.to_json());
    write_bench_json("BENCH_server.json", &record)?;
    println!("\nwrote BENCH_server.json (contended_mem + stage_wall + speedup_vs_serial + sessions + metrics)");
    write_trace(trace_out.as_deref(), trace_sink.as_ref())?;
    Ok(())
}
