fn main() {
    use gaucim::camera::ViewCondition;
    use gaucim::coordinator::App;
    use gaucim::pipeline::FramePipeline;
    use gaucim::render::RenderBackend;
    use gaucim::scene::synth::SceneKind;
    use std::time::Instant;
    let args: Vec<String> = std::env::args().collect();
    let n: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(600_000);
    let mut app = App::new(SceneKind::DynamicLarge, n, 42);
    app.config = app.config.clone().with_resolution(1280, 720);
    let traj = app.trajectory(ViewCondition::Average, 4);
    let t0 = Instant::now();
    let mut p = FramePipeline::new(&app.scene, app.config.clone());
    eprintln!("build (grid+layout): {:.1} ms", t0.elapsed().as_secs_f64()*1e3);
    eprintln!("render backend: {}", app.config.render_backend.label());
    for (i, (cam, t)) in traj.iter().enumerate() {
        let t0 = Instant::now();
        let r = p.render_frame(cam, *t, false);
        eprintln!("frame {i}: {:.1} ms (visible {})", t0.elapsed().as_secs_f64()*1e3, r.n_visible);
    }
    // Numeric blend datapath: one shaded frame per backend (bit-identical
    // pixels, different wall-clock — the lane kernel is the fast path).
    for backend in [RenderBackend::Scalar, RenderBackend::Lanes] {
        let cfg = app.config.clone().with_render_backend(backend);
        let mut p = FramePipeline::new(&app.scene, cfg);
        let (cam, t) = &traj[0];
        let t0 = Instant::now();
        let r = p.render_frame(cam, *t, true);
        eprintln!(
            "numeric frame [{}]: {:.1} ms (visible {})",
            backend.label(),
            t0.elapsed().as_secs_f64() * 1e3,
            r.n_visible
        );
    }
}
