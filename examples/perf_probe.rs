fn main() {
    use gaucim::coordinator::App;
    use gaucim::scene::synth::SceneKind;
    use gaucim::pipeline::FramePipeline;
    use gaucim::camera::ViewCondition;
    use std::time::Instant;
    let args: Vec<String> = std::env::args().collect();
    let n: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(600_000);
    let mut app = App::new(SceneKind::DynamicLarge, n, 42);
    app.config = app.config.clone().with_resolution(1280, 720);
    let traj = app.trajectory(ViewCondition::Average, 4);
    let t0 = Instant::now();
    let mut p = FramePipeline::new(&app.scene, app.config.clone());
    eprintln!("build (grid+layout): {:.1} ms", t0.elapsed().as_secs_f64()*1e3);
    for (i, (cam, t)) in traj.iter().enumerate() {
        let t0 = Instant::now();
        let r = p.render_frame(cam, *t, false);
        eprintln!("frame {i}: {:.1} ms (visible {})", t0.elapsed().as_secs_f64()*1e3, r.n_visible);
    }
}
