"""L1 exp2-LUT kernel vs oracles: bit-level vs ref model, tolerance vs exact."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import exp_lut, ref


def test_matches_bitfaithful_reference():
    x = jnp.linspace(-30.0, 10.0, 4096)
    got = exp_lut.exp2_lut(x)
    expect = ref.exp2_lut_ref(x, frac_bits=12)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(expect))


def test_close_to_exact_on_blend_range():
    # Blend exponents live in ~[-30, 0]; the 12-bit claim (paper §3.4).
    x = jnp.linspace(-30.0, 0.0, 10_000)
    got = np.asarray(exp_lut.exp2_lut(x))
    exact = np.exp2(np.asarray(x, dtype=np.float64))
    rel = np.abs(got - exact) / np.maximum(exact, 1e-300)
    assert rel.max() < 4e-3, f"max rel error {rel.max()}"


def test_integer_exponents_near_exact():
    x = jnp.arange(-20.0, 21.0)
    got = np.asarray(exp_lut.exp2_lut(jnp.pad(x, (0, 4096 - x.shape[0]))))[: x.shape[0]]
    exact = np.exp2(np.asarray(x))
    np.testing.assert_allclose(got, exact, rtol=1e-3)


def test_monotonic_nondecreasing():
    x = jnp.linspace(-12.0, 4.0, 4096)
    got = np.asarray(exp_lut.exp2_lut(x))
    assert (np.diff(got) >= -1e-6 * got[:-1]).all()


@settings(max_examples=30, deadline=None)
@given(
    st.lists(
        st.floats(min_value=-40.0, max_value=15.0, width=32),
        min_size=1,
        max_size=64,
    )
)
def test_hypothesis_relative_error(xs):
    x = jnp.asarray(xs, jnp.float32)
    got = np.asarray(exp_lut.exp2_lut(x), dtype=np.float64)
    exact = np.exp2(np.asarray(x, dtype=np.float64))
    ok = np.abs(got - exact) <= 4e-3 * exact + 1e-300
    assert ok.all(), f"failures at {np.asarray(x)[~ok]}"


@pytest.mark.parametrize("n", [1, 7, 256, 4096])
def test_shapes(n):
    x = jnp.zeros((n,), jnp.float32)
    out = exp_lut.exp2_lut(x)
    assert out.shape == (n,)
    np.testing.assert_allclose(np.asarray(out), 1.0, rtol=1e-3)
