"""L1 Pallas blend kernel vs the pure-jnp oracle."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import blend, ref


def random_splats(rng, g):
    means = rng.uniform(-4.0, 20.0, size=(g, 2)).astype(np.float32)
    a = rng.uniform(0.01, 0.5, size=g).astype(np.float32)
    c = rng.uniform(0.01, 0.5, size=g).astype(np.float32)
    b = (rng.uniform(-0.8, 0.8, size=g) * np.sqrt(a * c)).astype(np.float32)
    conics = np.stack([a, b, c], axis=-1)
    colors = rng.uniform(0.0, 1.0, size=(g, 3)).astype(np.float32)
    alphas = rng.uniform(0.05, 0.95, size=g).astype(np.float32)
    return means, conics, colors, alphas


def test_matches_reference():
    rng = np.random.default_rng(7)
    args = random_splats(rng, 64)
    got = blend.blend_tile(*map(jnp.asarray, args))
    expect = ref.blend_tile_ref(*map(jnp.asarray, args))
    np.testing.assert_allclose(np.asarray(got), np.asarray(expect), atol=1e-5, rtol=1e-5)


def test_empty_tile_black():
    g = 16
    z2 = jnp.zeros((g, 2))
    z3 = jnp.zeros((g, 3))
    z1 = jnp.zeros((g,))
    out = blend.blend_tile(z2, z3 + 0.5, z3 + 0.5, z1)
    np.testing.assert_array_equal(np.asarray(out), 0.0)


def test_padding_is_inert():
    rng = np.random.default_rng(3)
    means, conics, colors, alphas = random_splats(rng, 32)
    # Same splats padded to 64 with alpha=0 garbage.
    pad_means = np.concatenate([means, rng.uniform(size=(32, 2)).astype(np.float32)])
    pad_conics = np.concatenate([conics, np.abs(rng.uniform(size=(32, 3))).astype(np.float32)])
    pad_colors = np.concatenate([colors, rng.uniform(size=(32, 3)).astype(np.float32)])
    pad_alphas = np.concatenate([alphas, np.zeros(32, np.float32)])
    a = blend.blend_tile(*map(jnp.asarray, (means, conics, colors, alphas)))
    b = blend.blend_tile(*map(jnp.asarray, (pad_means, pad_conics, pad_colors, pad_alphas)))
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_front_to_back_order_matters():
    # Two coincident opaque splats: the first one must dominate.
    means = jnp.asarray([[8.0, 8.0], [8.0, 8.0]], jnp.float32)
    conics = jnp.asarray([[0.5, 0.0, 0.5]] * 2, jnp.float32)
    colors = jnp.asarray([[1.0, 0.0, 0.0], [0.0, 1.0, 0.0]], jnp.float32)
    alphas = jnp.asarray([0.9, 0.9], jnp.float32)
    out = np.asarray(blend.blend_tile(means, conics, colors, alphas))
    center = out[8 * ref.TILE_PX + 8]
    assert center[0] > 4.0 * center[1], center


def test_output_bounded():
    rng = np.random.default_rng(11)
    args = random_splats(rng, 128)
    out = np.asarray(blend.blend_tile(*map(jnp.asarray, args)))
    assert out.shape == (ref.TILE_PX * ref.TILE_PX, 3)
    assert (out >= -1e-6).all() and (out <= 1.0 + 1e-5).all()


@settings(max_examples=15, deadline=None)
@given(
    g=st.sampled_from([1, 2, 8, 33, 128]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_hypothesis_matches_reference(g, seed):
    rng = np.random.default_rng(seed)
    args = random_splats(rng, g)
    got = blend.blend_tile(*map(jnp.asarray, args))
    expect = ref.blend_tile_ref(*map(jnp.asarray, args))
    np.testing.assert_allclose(np.asarray(got), np.asarray(expect), atol=2e-5, rtol=1e-4)
