"""L2 preprocess graph: shape, culling-flag, and geometry checks."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from compile import model
from compile.kernels import ref

K = 32  # small chunk for tests (graph is shape-generic; AOT pins 1024)


def look_at_view(eye, target, up=(0.0, 1.0, 0.0)):
    """Row-major world->camera matrix, matching rust Camera::set_pose."""
    eye = np.asarray(eye, np.float32)
    target = np.asarray(target, np.float32)
    up = np.asarray(up, np.float32)
    f = target - eye
    f = f / np.linalg.norm(f)
    r = np.cross(f, up)
    r = r / np.linalg.norm(r)
    u = np.cross(r, f)
    view = np.eye(4, dtype=np.float32)
    view[0, :3], view[0, 3] = r, -r @ eye
    view[1, :3], view[1, 3] = u, -u @ eye
    view[2, :3], view[2, 3] = f, -f @ eye
    return view


def default_inputs(rng, k=K):
    mu = rng.uniform(-10, 10, size=(k, 3)).astype(np.float32)
    rot = rng.normal(size=(k, 4)).astype(np.float32)
    rot /= np.linalg.norm(rot, axis=1, keepdims=True)
    scale = rng.uniform(0.05, 0.5, size=(k, 3)).astype(np.float32)
    mu_t = rng.uniform(0, 1, size=k).astype(np.float32)
    lam = np.zeros(k, np.float32)  # static by default
    vel = np.zeros((k, 3), np.float32)
    opa = rng.uniform(0.3, 1.0, size=k).astype(np.float32)
    sh = np.zeros((k, 27), np.float32)
    sh[:, 0:3] = rng.uniform(-0.5, 0.5, size=(k, 3)) / 0.2820948
    view = look_at_view([0, 0, 25], [0, 0, 0])
    intr = np.asarray([100.0, 100.0, 64.0, 36.0], np.float32)
    t = np.asarray([0.5], np.float32)
    return [mu, rot, scale, mu_t, lam, vel, opa, sh, view, intr, t]


def run(args):
    return [np.asarray(o) for o in model.preprocess_chunk(*map(jnp.asarray, args))]


def test_output_shapes():
    rng = np.random.default_rng(1)
    mean2, conic, depth, alpha, color = run(default_inputs(rng))
    assert mean2.shape == (K, 2)
    assert conic.shape == (K, 3)
    assert depth.shape == (K,)
    assert alpha.shape == (K,)
    assert color.shape == (K, 3)


def test_center_gaussian_projects_to_principal_point():
    rng = np.random.default_rng(2)
    args = default_inputs(rng)
    args[0][0] = [0.0, 0.0, 0.0]
    mean2, _, depth, alpha, _ = run(args)
    assert abs(mean2[0, 0] - 64.0) < 1e-3
    assert abs(mean2[0, 1] - 36.0) < 1e-3
    assert abs(depth[0] - 25.0) < 1e-3
    assert alpha[0] > 0


def test_behind_camera_culled():
    rng = np.random.default_rng(3)
    args = default_inputs(rng)
    args[0][0] = [0.0, 0.0, 30.0]  # behind the eye at z=25 looking at -z
    _, _, _, alpha, _ = run(args)
    assert alpha[0] == 0.0


def test_temporal_slicing_weight_and_motion():
    rng = np.random.default_rng(4)
    args = default_inputs(rng)
    # Dynamic gaussian: sigma_t = 0.1 -> lam = 100; velocity +x.
    args[0][0] = [0.0, 0.0, 0.0]
    args[3][0] = 0.3   # mu_t
    args[4][0] = 100.0  # lam
    args[5][0] = [5.0, 0.0, 0.0]
    args[6][0] = 0.9   # opacity
    mean2, _, _, alpha, _ = run(args)
    # t = 0.5: dt = 0.2 -> weight exp(-0.5*100*0.04) = exp(-2).
    expect_alpha = 0.9 * np.exp(-2.0)
    np.testing.assert_allclose(alpha[0], expect_alpha, rtol=1e-4)
    # Mean moved +x by 5*0.2 = 1 world unit -> +fx*1/25 = 4 px.
    np.testing.assert_allclose(mean2[0, 0], 64.0 + 4.0, rtol=1e-3)


def test_temporally_dead_culled():
    rng = np.random.default_rng(5)
    args = default_inputs(rng)
    args[3][0] = 0.0
    args[4][0] = 1.0e4  # sigma_t = 0.01, t = 0.5 -> 50 sigma away
    _, _, _, alpha, _ = run(args)
    assert alpha[0] == 0.0


def test_conic_is_inverse_of_cov2d():
    rng = np.random.default_rng(6)
    args = default_inputs(rng)
    _, conic, _, alpha, _ = run(args)
    # conic = [A, B, C] with [A B; B C] = inv(cov2d): positive definite.
    live = alpha > 0
    a, b, c = conic[live, 0], conic[live, 1], conic[live, 2]
    assert (a > 0).all() and (c > 0).all()
    assert (a * c - b * b > 0).all()


def test_dc_only_sh_color_matches():
    rng = np.random.default_rng(7)
    args = default_inputs(rng)
    base = args[7][:, 0:3] * 0.2820948 + 0.5
    _, _, _, alpha, color = run(args)
    live = alpha > 0
    np.testing.assert_allclose(color[live], np.clip(base[live], 0, 1), atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_hypothesis_alpha_bounded_and_depth_sign(seed):
    rng = np.random.default_rng(seed)
    mean2, conic, depth, alpha, color = run(default_inputs(rng))
    assert (alpha >= 0).all() and (alpha <= 1.0).all()
    assert ((alpha == 0) | (depth >= 0.1)).all()
    assert (color >= 0).all() and (color <= 1).all()


def test_blend_tile_entry_point():
    # The L2 wrapper executes the Pallas kernel.
    g = 8
    means = jnp.full((g, 2), 8.0)
    conics = jnp.tile(jnp.asarray([[0.5, 0.0, 0.5]]), (g, 1))
    colors = jnp.ones((g, 3)) * 0.5
    alphas = jnp.ones((g,)) * 0.5
    out = model.blend_tile(means, conics, colors, alphas)
    assert out.shape == (ref.TILE_PX * ref.TILE_PX, 3)
    assert float(out.max()) > 0.1


def test_render_tiles_shifts_origins():
    g = 4
    means = jnp.asarray([[24.0, 8.0]] * g)
    conics = jnp.tile(jnp.asarray([[0.5, 0.0, 0.5]]), (g, 1))
    colors = jnp.ones((g, 3))
    alphas = jnp.ones((g,)) * 0.7
    tiles = model.render_tiles((means, conics, colors, alphas), [(0.0, 0.0), (16.0, 0.0)])
    t0 = np.asarray(tiles[0]).reshape(16, 16, 3)
    t1 = np.asarray(tiles[1]).reshape(16, 16, 3)
    # The splat at x=24 lives in the second tile.
    assert t1.max() > 0.5
    assert t0[:, :8].max() < 1e-3
