"""AOT lowering: artifacts parse as HLO text with the pinned shapes."""

import pytest

from compile import aot, model


@pytest.fixture(scope="module")
def lowered():
    return {name: fn() for name, fn in aot.ARTIFACTS.items()}


def test_all_artifacts_lower(lowered):
    assert set(lowered) == {
        "preprocess.hlo.txt",
        "blend.hlo.txt",
        "exp_lut.hlo.txt",
    }
    for name, text in lowered.items():
        assert text.startswith("HloModule"), name
        assert len(text) > 1000, f"{name} suspiciously small"


def test_preprocess_shapes_pinned(lowered):
    text = lowered["preprocess.hlo.txt"]
    k = model.PREPROCESS_CHUNK
    assert f"f32[{k},3]" in text  # mu / scale / vel / colors
    assert f"f32[{k},27]" in text  # sh
    assert "f32[4,4]" in text  # view


def test_blend_shapes_pinned(lowered):
    text = lowered["blend.hlo.txt"]
    g = model.BLEND_MAX_G
    assert f"f32[{g},2]" in text
    assert f"f32[256,3]" in text  # output tile


def test_exp_lut_shape_pinned(lowered):
    assert f"f32[{model.EXP_LUT_N}]" in lowered["exp_lut.hlo.txt"]


def test_deterministic_lowering():
    a = aot.lower_exp_lut()
    b = aot.lower_exp_lut()
    assert a == b
