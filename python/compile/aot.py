"""AOT lowering: JAX (L2 + L1) -> HLO **text** artifacts for the rust PJRT
runtime.

HLO text (not ``.serialize()``) is the interchange format: jax >= 0.5 emits
protos with 64-bit instruction ids which xla_extension 0.5.1 (the version
the published ``xla`` crate binds) rejects; the text parser reassigns ids.
See /opt/xla-example/gen_hlo.py.

Run via ``make artifacts`` (no-op when artifacts are newer than sources):

    cd python && python -m compile.aot --out-dir ../artifacts
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels import exp_lut


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True so the
    rust side always unwraps a tuple)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_preprocess() -> str:
    k = model.PREPROCESS_CHUNK
    f32 = jnp.float32
    spec = jax.ShapeDtypeStruct
    lowered = jax.jit(model.preprocess_chunk).lower(
        spec((k, 3), f32),   # mu
        spec((k, 4), f32),   # rot
        spec((k, 3), f32),   # scale
        spec((k,), f32),     # mu_t
        spec((k,), f32),     # lam
        spec((k, 3), f32),   # vel
        spec((k,), f32),     # opa
        spec((k, 27), f32),  # sh
        spec((4, 4), f32),   # view
        spec((4,), f32),     # intr
        spec((1,), f32),     # t
    )
    return to_hlo_text(lowered)


def lower_blend() -> str:
    g = model.BLEND_MAX_G
    f32 = jnp.float32
    spec = jax.ShapeDtypeStruct
    lowered = jax.jit(model.blend_tile).lower(
        spec((g, 2), f32),
        spec((g, 3), f32),
        spec((g, 3), f32),
        spec((g,), f32),
    )
    return to_hlo_text(lowered)


def lower_exp_lut() -> str:
    n = model.EXP_LUT_N
    lowered = jax.jit(exp_lut.exp2_lut).lower(
        jax.ShapeDtypeStruct((n,), jnp.float32)
    )
    return to_hlo_text(lowered)


ARTIFACTS = {
    "preprocess.hlo.txt": lower_preprocess,
    "blend.hlo.txt": lower_blend,
    "exp_lut.hlo.txt": lower_exp_lut,
}


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out-dir", default="../artifacts")
    parser.add_argument(
        "--only", choices=sorted(ARTIFACTS), default=None,
        help="lower a single artifact",
    )
    args = parser.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    for name, fn in ARTIFACTS.items():
        if args.only and name != args.only:
            continue
        text = fn()
        path = os.path.join(args.out_dir, name)
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {path} ({len(text)} chars)")


if __name__ == "__main__":
    main()
