"""Pure-jnp oracles for the Pallas kernels and the preprocess math.

These are the CORE correctness references: every kernel in this package and
the rust-side projection/blending are validated against these functions
(pytest here, parity tests on the rust side through the AOT artifacts).
"""

import jax.numpy as jnp
import numpy as np

# Shared constants (must match rust/src/tiles/intersect.rs).
TILE_PX = 16
ALPHA_CUTOFF = 1.0 / 255.0
ALPHA_CLAMP = 0.999
COV2D_DILATION = 0.3
# Exponent cutoff shared with the rust renderers (reference.rs EXP_CUTOFF).
EXP_CUTOFF = -14.0


def exp2_exact(x):
    """Exact base-2 exponential (the oracle for the LUT kernel)."""
    return jnp.exp2(x)


def exp2_lut_ref(x, frac_bits=12):
    """Bit-faithful model of the DD3D-Flow exp2 (paper §3.4 / Fig. 8(a)).

    SIF decouple, then a 4-segment cascade of 8-entry FP16 LUTs with FP16
    intermediate products — mirrors rust ``dcim::exp_lut`` exactly.
    """
    segments = 4
    bps = frac_bits // segments
    x = jnp.asarray(x, jnp.float32)
    i = jnp.floor(x)
    frac = x - i
    scale = float(1 << frac_bits)
    q = jnp.clip((frac * scale).astype(jnp.int32), 0, (1 << frac_bits) - 1)

    acc = jnp.ones_like(x)
    for k in range(segments):
        shift = frac_bits - bps * (k + 1)
        idx = (q >> shift) & ((1 << bps) - 1)
        weight = 2.0 ** (-(bps) * (k + 1))
        # 8-entry table, FP16-quantized entries.
        table = np.float16(2.0 ** (np.arange(8) * weight)).astype(np.float32)
        acc = (acc * jnp.asarray(table)[jnp.clip(idx, 0, 7)]).astype(jnp.float16).astype(jnp.float32)
    return acc * jnp.exp2(i)


def blend_tile_ref(means, conics, colors, alphas):
    """Cumulative front-to-back tile blend (paper eqs. 9–10), no early exit.

    Args:
      means:  [G, 2] splat centers relative to the tile origin (pixels).
      conics: [G, 3] inverse-covariance coefficients (a, b, c).
      colors: [G, 3] RGB.
      alphas: [G] base opacity (0 = padding); splats are depth-ordered.

    Returns: [TILE_PX * TILE_PX, 3] RGB rows (row-major pixels).
    """
    ys, xs = jnp.meshgrid(
        jnp.arange(TILE_PX, dtype=jnp.float32) + 0.5,
        jnp.arange(TILE_PX, dtype=jnp.float32) + 0.5,
        indexing="ij",
    )
    px = xs.reshape(-1)  # [P]
    py = ys.reshape(-1)

    dx = px[None, :] - means[:, 0:1]  # [G, P]
    dy = py[None, :] - means[:, 1:2]
    e = -0.5 * (
        conics[:, 0:1] * dx * dx
        + 2.0 * conics[:, 1:2] * dx * dy
        + conics[:, 2:3] * dy * dy
    )
    alpha = jnp.minimum(alphas[:, None] * jnp.exp(e), ALPHA_CLAMP)
    alpha = jnp.where(e < EXP_CUTOFF, 0.0, alpha)
    alpha = jnp.where(alpha < ALPHA_CUTOFF, 0.0, alpha)  # [G, P]

    # Transmittance before each splat: exclusive cumprod along G.
    trans = jnp.cumprod(1.0 - alpha, axis=0)
    trans = jnp.concatenate([jnp.ones_like(trans[:1]), trans[:-1]], axis=0)
    w = alpha * trans  # [G, P]
    rgb = jnp.einsum("gp,gc->pc", w, colors)
    return rgb


def quat_to_mat(q):
    """Unit quaternions (w,x,y,z) [N,4] -> rotation matrices [N,3,3]."""
    w, x, y, z = q[:, 0], q[:, 1], q[:, 2], q[:, 3]
    x2, y2, z2 = x + x, y + y, z + z
    xx, yy, zz = x * x2, y * y2, z * z2
    xy, xz, yz = x * y2, x * z2, y * z2
    wx, wy, wz = w * x2, w * y2, w * z2
    m = jnp.stack(
        [
            1.0 - (yy + zz), xy - wz, xz + wy,
            xy + wz, 1.0 - (xx + zz), yz - wx,
            xz - wy, yz + wx, 1.0 - (xx + yy),
        ],
        axis=-1,
    )
    return m.reshape(-1, 3, 3)


def sh_basis(dirs):
    """Real SH basis (degree 2) for unit directions [N,3] -> [N,9].

    Must match rust scene::gaussian::sh_basis.
    """
    C0 = 0.2820948
    C1 = 0.4886025
    C2 = jnp.asarray([1.0925484, 1.0925484, 0.31539157, 1.0925484, 0.5462742])
    x, y, z = dirs[:, 0], dirs[:, 1], dirs[:, 2]
    return jnp.stack(
        [
            jnp.full_like(x, C0),
            -C1 * y,
            C1 * z,
            -C1 * x,
            C2[0] * x * y,
            C2[1] * y * z,
            C2[2] * (2.0 * z * z - x * x - y * y),
            C2[3] * x * z,
            C2[4] * (x * x - y * y),
        ],
        axis=-1,
    )


def preprocess_ref(mu, rot, scale, mu_t, lam, vel, opa, sh, view, intr, t):
    """Oracle for the L2 preprocess graph (paper eqs. 4–8 + SH color).

    Shapes: mu[K,3] rot[K,4] scale[K,3] mu_t[K] lam[K] vel[K,3] opa[K]
    sh[K,27] view[4,4] intr[4]=(fx,fy,cx,cy) t scalar.
    Returns (mean2[K,2], conic[K,3], depth[K], alpha[K], color[K,3]).
    alpha = 0 flags culled entries (temporal cutoff / behind near plane /
    sub-cutoff opacity).
    """
    fx, fy, cx, cy = intr[0], intr[1], intr[2], intr[3]
    near = 0.1

    # Temporal slice (eqs. 4–5). λ = 0 ⇒ static (weight 1).
    dt = t - mu_t
    w_t = jnp.where(lam > 0.0, jnp.exp(-0.5 * lam * dt * dt), 1.0)
    alpha0 = opa * w_t
    mean3 = mu + vel * jnp.where(lam > 0.0, dt, 0.0)[:, None]

    # World -> camera.
    r_view = view[:3, :3]
    t_view = view[:3, 3]
    pc = mean3 @ r_view.T + t_view  # [K,3]
    depth = pc[:, 2]

    # Conditional 3-D covariance Σ = R diag(s²) Rᵀ (eq. 6).
    rmat = quat_to_mat(rot)
    s2 = scale * scale
    cov3 = jnp.einsum("nij,nj,nkj->nik", rmat, s2, rmat)

    # Projection Jacobian (eq. 8).
    zc = jnp.maximum(pc[:, 2], 1e-6)
    zeros = jnp.zeros_like(zc)
    j = jnp.stack(
        [
            fx / zc, zeros, -fx * pc[:, 0] / (zc * zc),
            zeros, fy / zc, -fy * pc[:, 1] / (zc * zc),
            zeros, zeros, zeros,
        ],
        axis=-1,
    ).reshape(-1, 3, 3)
    jw = jnp.einsum("nij,jk->nik", j, r_view)
    cov2_full = jnp.einsum("nij,njk,nlk->nil", jw, cov3, jw)
    a = jnp.maximum(cov2_full[:, 0, 0] + COV2D_DILATION, 1e-6)
    b = cov2_full[:, 0, 1]
    c = jnp.maximum(cov2_full[:, 1, 1] + COV2D_DILATION, 1e-6)
    det = a * c - b * b
    safe_det = jnp.where(det > 0.0, det, 1.0)
    conic = jnp.stack([c / safe_det, -b / safe_det, a / safe_det], axis=-1)

    mean2 = jnp.stack(
        [fx * pc[:, 0] / zc + cx, fy * pc[:, 1] / zc + cy], axis=-1
    )

    # View-dependent color from SH (matches rust Gaussian4D::sh_color).
    cam_pos = -(r_view.T @ t_view)
    dirs = mean3 - cam_pos[None, :]
    dirs = dirs / jnp.maximum(jnp.linalg.norm(dirs, axis=-1, keepdims=True), 1e-9)
    basis = sh_basis(dirs)  # [K,9]
    color = jnp.einsum("nk,nkc->nc", basis, sh.reshape(-1, 9, 3)) + 0.5
    color = jnp.clip(color, 0.0, 1.0)

    valid = (depth >= near) & (det > 0.0) & (alpha0 >= ALPHA_CUTOFF)
    alpha = jnp.where(valid, alpha0, 0.0)
    return mean2, conic, depth, alpha, color
