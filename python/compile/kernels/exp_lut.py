"""L1 Pallas kernel: the DD3D-Flow base-2 exponential (paper §3.4).

The DCIM dataflow in kernel form — phase 2 of DD3D-Flow (phase 1, the
e^x -> 2^(x/ln2) base conversion, is fused offline into the parameters):

1. **SIF decouple**: x = int + frac, frac in [0, 1) (two's-complement
   handling of negative x falls out of the floor);
2. **cascaded LUT**: the 12-bit fraction splits into four 3-bit segments;
   each indexes an 8-entry FP16 table (2^(s*2^-3k)) and the four factors
   multiply in cascade — exactly the paper's "12-bit LUT divided into four
   segments, each requiring 8 LUT values ... four cascaded DCIM stages";
3. `2^int` is an exponent shift (exact).

In the Pallas/TPU mapping the four tables are 32 VMEM words; the gathers are
the in-memory-LUT analogue. FP16 casts between stages reproduce the DCIM
arrays' storage precision, so this kernel is bit-comparable to the rust
`dcim::exp_lut` implementation.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

FRAC_BITS = 12
SEGMENTS = 4
BPS = FRAC_BITS // SEGMENTS  # 3 bits per segment


def _tables():
    """The four 8-entry FP16 LUTs, as f32 (FP16-quantized values)."""
    tabs = []
    for k in range(SEGMENTS):
        weight = 2.0 ** (-BPS * (k + 1))
        tabs.append(np.float16(2.0 ** (np.arange(8) * weight)).astype(np.float32))
    return np.stack(tabs)  # [4, 8]


_TABLES = _tables()


def _exp2_kernel(x_ref, tables_ref, out_ref):
    x = x_ref[...]
    i = jnp.floor(x)
    frac = x - i
    scale = float(1 << FRAC_BITS)
    q = jnp.clip((frac * scale).astype(jnp.int32), 0, (1 << FRAC_BITS) - 1)

    tables = tables_ref[...]
    acc = jnp.ones_like(x)
    for k in range(SEGMENTS):
        shift = FRAC_BITS - BPS * (k + 1)
        idx = (q >> shift) & ((1 << BPS) - 1)
        stage = jnp.take(tables[k], idx)
        # FP16 intermediate product — the DCIM array storage precision.
        acc = (acc * stage).astype(jnp.float16).astype(jnp.float32)
    out_ref[...] = acc * jnp.exp2(i)


@functools.partial(jax.jit, static_argnames=())
def exp2_lut(x):
    """Vector 2^x through the DD3D-Flow LUT path. x: [N] f32 -> [N] f32."""
    n = x.shape[0]
    return pl.pallas_call(
        _exp2_kernel,
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=True,
    )(x.astype(jnp.float32), jnp.asarray(_TABLES))
