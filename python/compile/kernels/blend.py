"""L1 Pallas kernel: 16x16-tile alpha blending (paper eqs. 9-10).

Hardware adaptation (DESIGN.md §6): the paper's DCIM evaluates one merged
exponent per (pixel, splat) pair in gain-cell arrays and accumulates the
transmittance in NMC units. On the TPU-shaped Pallas model we express the
same computation as dense [G, P] matrix work resident in VMEM:

* the merged exponent for all 256 pixels x G splats at once (outer-product
  structured quadratic form -> VPU elementwise);
* the transmittance as an exclusive cumulative product along the depth axis
  (the NMC serial accumulation, vectorized as a scan);
* the weighted color accumulation as a [P, G] x [G, 3] matmul (MXU work).

One tile's splat parameters (G=128 x 9 f32 ~ 4.5 KB) plus the [G, P] alpha
matrix (128 x 256 x 4 B = 128 KB) fit comfortably in VMEM, mirroring the
paper's depth-segmented SRAM sizing.

interpret=True everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls; real-TPU performance is estimated in DESIGN.md §8.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

TILE_PX = ref.TILE_PX
N_PIX = TILE_PX * TILE_PX


def _blend_kernel(means_ref, conics_ref, colors_ref, alphas_ref, out_ref):
    """Pallas kernel body: blends all splats into the tile's pixels."""
    means = means_ref[...]  # [G, 2]
    conics = conics_ref[...]  # [G, 3]
    colors = colors_ref[...]  # [G, 3]
    alphas = alphas_ref[...]  # [G]

    # Pixel-center coordinates of the 16x16 tile, flattened row-major.
    pix = jax.lax.iota(jnp.float32, N_PIX)
    px = jnp.mod(pix, TILE_PX) + 0.5  # [P]
    py = jnp.floor(pix / TILE_PX) + 0.5

    # Merged exponent for every (splat, pixel) pair.
    dx = px[None, :] - means[:, 0:1]  # [G, P]
    dy = py[None, :] - means[:, 1:2]
    e = -0.5 * (
        conics[:, 0:1] * dx * dx
        + 2.0 * conics[:, 1:2] * dx * dy
        + conics[:, 2:3] * dy * dy
    )
    alpha = jnp.minimum(alphas[:, None] * jnp.exp(e), ref.ALPHA_CLAMP)
    alpha = jnp.where(e < ref.EXP_CUTOFF, 0.0, alpha)
    alpha = jnp.where(alpha < ref.ALPHA_CUTOFF, 0.0, alpha)

    # Exclusive transmittance along the (depth-sorted) splat axis.
    trans = jnp.cumprod(1.0 - alpha, axis=0)
    trans = jnp.concatenate([jnp.ones_like(trans[:1]), trans[:-1]], axis=0)
    w = alpha * trans  # [G, P]

    # Weighted color accumulation: [P, G] @ [G, 3] — MXU-shaped.
    out_ref[...] = jnp.dot(w.T, colors)


@functools.partial(jax.jit, static_argnames=())
def blend_tile(means, conics, colors, alphas):
    """Blend one tile. Shapes: means[G,2] conics[G,3] colors[G,3] alphas[G]
    (alpha 0 = padding; splats depth-ordered front-first).
    Returns rgb[N_PIX, 3]."""
    g = means.shape[0]
    return pl.pallas_call(
        _blend_kernel,
        out_shape=jax.ShapeDtypeStruct((N_PIX, 3), jnp.float32),
        interpret=True,
    )(
        means.astype(jnp.float32).reshape(g, 2),
        conics.astype(jnp.float32).reshape(g, 3),
        colors.astype(jnp.float32).reshape(g, 3),
        alphas.astype(jnp.float32).reshape(g),
    )
