"""L2 JAX model: the 4DGS preprocessing graph (paper eqs. 4-8) and the tile
blend entry point that calls the L1 Pallas kernel.

These are the functions `aot.py` lowers to HLO text; the rust runtime
(`rust/src/runtime/`) loads and executes them via PJRT on the frame path.
The math here must stay in lock-step with:

* `kernels/ref.py` — the pure-jnp oracle (pytest checks);
* `rust/src/tiles/intersect.rs` — the rust projection (parity tests through
  the artifacts).
"""

import jax.numpy as jnp

from .kernels import blend as blend_kernel
from .kernels import ref

# Fixed AOT shapes (must match rust/src/runtime/mod.rs).
PREPROCESS_CHUNK = 1024
BLEND_MAX_G = 128
EXP_LUT_N = 4096


def preprocess_chunk(mu, rot, scale, mu_t, lam, vel, opa, sh, view, intr, t):
    """Temporal slice + projection + SH color for a padded Gaussian chunk.

    Inputs (fixed shapes, K = PREPROCESS_CHUNK):
      mu[K,3] rot[K,4] scale[K,3] mu_t[K] lam[K] vel[K,3] opa[K] sh[K,27]
      view[4,4] (world->camera, row-major) intr[4] = (fx, fy, cx, cy) t[1].

    Outputs: (mean2[K,2], conic[K,3], depth[K], alpha[K], color[K,3]);
    alpha = 0 marks culled/padding entries.

    The body IS the oracle — L2 owns this math; `ref.preprocess_ref` and this
    function are intentionally the same code path so the AOT artifact is the
    oracle lowered (divergence is impossible by construction). The rust
    projection is the independent implementation both are tested against.
    """
    return ref.preprocess_ref(
        mu, rot, scale, mu_t, lam, vel, opa, sh, view, intr, t[0]
    )


def blend_tile(means, conics, colors, alphas):
    """Blend one 16x16 tile over up to BLEND_MAX_G depth-sorted splats.

    Thin L2 wrapper over the L1 Pallas kernel so the lowered HLO contains
    the kernel's computation.
    """
    return blend_kernel.blend_tile(means, conics, colors, alphas)


def render_tiles(splat_args, tile_origins):
    """Demo composition: blend several tiles by shifting splat means to each
    tile origin. Used by tests to check multi-tile consistency; the real
    multi-tile loop lives in the rust coordinator."""
    means, conics, colors, alphas = splat_args
    outs = []
    for ox, oy in tile_origins:
        shifted = means - jnp.asarray([ox, oy], jnp.float32)[None, :]
        outs.append(blend_tile(shifted, conics, colors, alphas))
    return jnp.stack(outs)
